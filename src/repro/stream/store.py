"""GraphStore — the versioned multi-view update plane of `repro.stream`.

Meerkat's evaluation loop (apply a batch of edge inserts/deletes, then
incrementally recompute analytics) is the inner loop of a streaming-graph
service.  The store owns that loop end-to-end: it holds the forward,
transposed, and symmetric `SlabGraph` views as ONE versioned unit and applies
every update batch to all of them consistently, so algorithm code can always
pick the view its sweep direction wants (DESIGN.md §3) without ever seeing a
half-updated pair of views.

Contract per ``apply(inserts, deletes)`` (DESIGN.md §5/§6):

  1. the batch is canonicalised ONCE on the host (``canonical_batch``:
     dedup both halves, pad to a power-of-two lane count) — the transpose
     and symmetric batches are *derived* from that one canonical batch on
     device (swap / concat), never re-deduped or re-hashed per view,
  2. ``ensure_capacity`` runs automatically on every live view (growth is
     power-of-two quantized, so repeated growth walks a small ladder of
     pool shapes),
  3. deletions apply before insertions (a pair present in both ends the epoch
     *present*),
  4. the symmetric view is maintained as the true union of both directions:
     deleting (s,d) removes (s,d)/(d,s) from it only when the reverse edge
     (d,s) is itself absent from the post-delete forward view,
  5. out-degrees stay on device (``store.out_degree`` IS the forward view's
     ``degree`` field — no host shadow),
  6. registered listeners (the property registry) are notified while the
     update epoch is still OPEN, then every view's epoch is closed via
     ``update_slab_pointers`` and the monotonic ``version`` has been bumped,
  7. with a ``MaintenancePolicy`` attached, the closed epoch is inspected
     (``pool_stats``) and — on a trigger — every view compacts or reclaims
     as one versioned unit (DESIGN.md §8): a ``maintenance=True`` batch
     bumps the version and notifies listeners, vertex-keyed property
     states survive, retained slab handles are invalidated via the
     compaction permutation.

All live views mutate through ONE ``update_views`` dispatch (the stacked
slab-update engine invocation, DESIGN.md §6) with their buffers donated —
the pools update in place.  Consequence: a ``SlabGraph`` obtained from
``store.forward``/``.transpose``/``.symmetric`` is only valid until the
next ``apply``; re-read the property after each epoch (move semantics,
like the GPU original's in-place slab writes).

A bounded log of applied batches supports lazy property catch-up
(``batches_since``); when the log has been truncated the registry falls back
to a static refresh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..obs import flight as _flight
from ..core.batch import query_edges, update_views
from ..core.hashing import INVALID_VERTEX
from ..core.slab_graph import (SlabGraph, empty, ensure_capacity,
                               from_edges_host, next_pow2,
                               update_slab_pointers)
from ..core.worklist import EdgeFrontier, expand_vertices
from ..resilience import faults
from ..resilience.guard import (RetryBudget, run_with_retries,
                                validate_batch)

FORWARD = "forward"
TRANSPOSE = "transpose"
SYMMETRIC = "symmetric"
ALL_VIEWS = (FORWARD, TRANSPOSE, SYMMETRIC)

# Flight-recorder codes (interned once at import): each apply phase writes
# one ring event even when tracing/metrics are off, so a post-mortem's
# last-N window shows exactly which phase an epoch last cleared.
_FL_ADMIT = _flight.intern("store.apply.admitted")
_FL_GROW = _flight.intern("store.capacity_grow")
_FL_POST_WAL = _flight.intern("store.apply.post_wal")
_FL_DISPATCH = _flight.intern("store.apply.dispatch")
_FL_CLOSE = _flight.intern("store.apply.close")
_FL_MAINTAIN = _flight.intern("store.maintain")


# Batch lane counts quantize through the same pow2 ladder as pool growth.
_pow2 = next_pow2


def _pad_u32(a: np.ndarray, n: int) -> jnp.ndarray:
    out = np.full(n, INVALID_VERTEX, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def _pad_f32(a: Optional[np.ndarray], n: int) -> Optional[jnp.ndarray]:
    if a is None:
        return None
    out = np.zeros(n, np.float32)
    out[:len(a)] = a
    return jnp.asarray(out)


def dedup_pairs(src, dst, w=None) -> Tuple[np.ndarray, np.ndarray,
                                           Optional[np.ndarray]]:
    """Host-side (src,dst) dedup, first occurrence wins (insert semantics)."""
    src = np.asarray(src, dtype=np.uint32)
    dst = np.asarray(dst, dtype=np.uint32)
    w = None if w is None else np.asarray(w, dtype=np.float32)
    if len(src) == 0:
        return src, dst, w
    key = (src.astype(np.uint64) << np.uint64(32)) | dst.astype(np.uint64)
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    return src[idx], dst[idx], None if w is None else w[idx]


def canonical_batch(ins_src, ins_dst, ins_w, del_src, del_dst, *,
                    weighted: bool):
    """THE one host-side canonicalisation per ``apply``: dedup the insert
    and delete halves (first occurrence wins) and default missing insert
    weights on weighted stores.  Every per-view batch is derived from this
    canonical batch on device — no view re-dedups."""
    i_s, i_d, i_w = dedup_pairs(
        () if ins_src is None else ins_src,
        () if ins_dst is None else ins_dst, ins_w)
    d_s, d_d, _ = dedup_pairs(
        () if del_src is None else del_src,
        () if del_dst is None else del_dst)
    if weighted and len(i_s) and i_w is None:
        i_w = np.ones(len(i_s), np.float32)
    return i_s, i_d, i_w, d_s, d_d


@dataclasses.dataclass(frozen=True)
class AppliedBatch:
    """One closed update epoch, as seen by incremental property maintainers.

    Arrays are the padded device batches the views were mutated with; the
    masks mark edges *actually* inserted into / deleted from the forward view
    (duplicates and misses excluded).  ``ins_src is None`` means the epoch had
    no insert phase (likewise deletes).
    """
    version: int
    ins_src: Optional[jnp.ndarray]
    ins_dst: Optional[jnp.ndarray]
    ins_w: Optional[jnp.ndarray]
    ins_mask: Optional[jnp.ndarray]
    del_src: Optional[jnp.ndarray]
    del_dst: Optional[jnp.ndarray]
    del_mask: Optional[jnp.ndarray]
    n_inserted: int
    n_deleted: int
    #: epoch was a maintenance pass (compaction / slab reclamation): the
    #: edge set is untouched, vertex-keyed property states stay valid, and
    #: replay skips it — only retained slab handles are invalidated.
    maintenance: bool = False


class VersionedStoreBase:
    """The version / bounded-log / listener protocol both stores speak.

    This is the contract ``PropertyRegistry``'s catch-up relies on
    (``version`` monotonic, ``batches_since`` None past the log floor,
    listeners notified while the epoch is still open) — shared so the
    unsharded ``GraphStore`` and the ``ShardedGraphStore`` cannot drift.
    """

    def __init__(self, *, version: int = 0, log_capacity: int = 64,
                 maintenance=None):
        self.version = int(version)
        self._log_capacity = int(log_capacity)
        self._log: List[AppliedBatch] = []
        self._log_floor = int(version)  # version the oldest logged batch follows
        self._listeners: List[Callable[[AppliedBatch], None]] = []
        #: Optional MaintenancePolicy — evaluated at every epoch close.
        self.maintenance = maintenance
        self.maintenance_count = 0
        self.last_maintenance = None
        self._epochs_since_maint = 0
        #: per-view worst-case slab reservation of the most recent insert
        #: epoch — compaction keeps this much headroom so a shrunk pool
        #: doesn't have to grow right back for the next same-sized batch
        #: (no shrink/grow flapping at a pow2 rung edge).
        self._last_reserve: Dict[str, int] = {}
        #: exact tombstone accounting so the per-epoch policy check stays
        #: O(1): every recorded delete mints exactly one tombstone lane,
        #: and only maintenance ever clears them.
        self._tombstone_base = 0       # tombstones at the last maintenance
        self._deletes_since_maint = 0
        #: structured per-pass event stream (DESIGN.md §10): one dict per
        #: maintenance pass — trigger, tombstone ratio, capacity movement,
        #: slabs reclaimed — bounded like the batch log.  Mirrored into
        #: ``obs.metrics`` events when telemetry is on.
        self.maintenance_events: List[dict] = []
        # ----------------------------------------------- resilience plane
        #: optional WriteAheadLog — every apply journals its canonical
        #: batch (fsync) BEFORE the donated dispatch (DESIGN.md §11)
        self.wal = None
        #: optional AuditPolicy — pool invariant audits every N epochs
        self.audits = None
        self._epochs_since_audit = 0
        #: bounded stream of InvariantReport events (like maintenance_events)
        self.audit_events: List[dict] = []
        #: bounded retry-with-backoff for transient capacity-grow failures
        self.retry = RetryBudget()

    # ----------------------------------------------------- resilience plane
    def attach_wal(self, wal) -> "VersionedStoreBase":
        """Journal every applied batch through ``wal`` (fsync-before-
        dispatch); pair with ``save``/``resilience.recover`` for
        crash-exact recovery.  Returns self."""
        self.wal = wal
        return self

    def attach_audits(self, policy) -> "VersionedStoreBase":
        """Run pool invariant audits on the policy's cadence.  Returns
        self."""
        self.audits = policy
        return self

    def _wal_append(self, i_s, i_d, i_w, d_s, d_d):
        """Durably journal the canonical batch for version+1 (the version
        ``_record_batch`` will assign); returns the rollback token or
        None when no WAL is attached."""
        if self.wal is None:
            return None
        with obs.span("store.apply.wal", version=self.version):
            token = self.wal.append(self.version + 1, i_s, i_d, i_w,
                                    d_s, d_d)
        obs.inc("store.wal.appends")
        return token

    def audit(self, *, views=None, cross_view: bool = True):
        """Run the pool invariant audit now; returns the
        ``InvariantReport`` (also appended to ``audit_events``)."""
        from ..resilience.invariants import audit_store
        report = audit_store(self, views=views, cross_view=cross_view)
        self.audit_events.append(report.as_event())
        if len(self.audit_events) > self._log_capacity:
            self.audit_events = self.audit_events[-self._log_capacity:]
        return report

    def _auto_audit(self) -> None:
        """Epoch-close hook: audit on the AuditPolicy cadence."""
        if self.audits is None or not self.audits.every:
            return
        self._epochs_since_audit += 1
        if self._epochs_since_audit < self.audits.every:
            return
        self._epochs_since_audit = 0
        report = self.audit(views=self.audits.views,
                            cross_view=self.audits.cross_view)
        if not report.ok and self.audits.fail_fast:
            from ..resilience.invariants import InvariantViolationError
            raise InvariantViolationError(report)

    def _dump_postmortem(self, exc: BaseException) -> None:
        """Crash hook (apply's ``except BaseException``): write the
        black-box post-mortem bundle beside the WAL.  Best-effort and
        silent on the pipeline-recoverable classes — the exception itself
        still propagates to the caller either way."""
        from ..obs import postmortem
        postmortem.on_apply_failure(self, exc)

    def _resilience_meta(self) -> dict:
        """Host-side counters a checkpoint must carry so a recovered
        store's maintenance triggers replay exactly like the crashed
        process's would have (WAL replay determinism)."""
        return {"epochs_since_maint": int(self._epochs_since_maint),
                "deletes_since_maint": int(self._deletes_since_maint),
                "tombstone_base": int(self._tombstone_base),
                "last_reserve": {k: int(v)
                                 for k, v in self._last_reserve.items()}}

    def _adopt_resilience_meta(self, meta: dict) -> None:
        res = meta.get("resilience")
        if not res:
            return
        self._epochs_since_maint = int(res.get("epochs_since_maint", 0))
        self._deletes_since_maint = int(res.get("deletes_since_maint", 0))
        self._tombstone_base = int(res.get("tombstone_base", 0))
        self._last_reserve = {k: int(v)
                              for k, v in res.get("last_reserve",
                                                  {}).items()}

    def add_listener(self, fn: Callable[[AppliedBatch], None]) -> None:
        """Subscribe to applied batches (called with the epoch still open)."""
        self._listeners.append(fn)

    def batches_since(self, version: int) -> Optional[List[AppliedBatch]]:
        """Applied batches after ``version``, oldest first; None if the
        bounded log no longer reaches back that far."""
        if version == self.version:
            return []
        if version < self._log_floor:
            return None
        return [b for b in self._log if b.version > version]

    def _record_batch(self, **fields) -> AppliedBatch:
        """Bump the version, log the batch, notify listeners (epoch open)."""
        self.version += 1
        batch = AppliedBatch(version=self.version, **fields)
        self._log.append(batch)
        if len(self._log) > self._log_capacity:
            self._log = self._log[-self._log_capacity:]
            self._log_floor = self._log[0].version - 1
        if not batch.maintenance:
            self._deletes_since_maint += batch.n_deleted
        for fn in self._listeners:
            fn(batch)
        return batch

    # ----------------------------------------------------- maintenance plane
    def pool_stats(self, view: str = "forward") -> dict:
        raise NotImplementedError

    def _compact_view(self, view, policy, *, shrink: bool, slack_slabs: int):
        """(compacted view, CompactionReport) — per-store-kind hook."""
        raise NotImplementedError

    def _reclaim_view(self, view):
        """(reclaimed view, n_freed) — per-store-kind hook."""
        raise NotImplementedError

    def _maintain_views(self, action: str, policy, *, shrink: bool):
        """Apply one maintenance action to every live view (the loop is
        shared so the two store kinds cannot drift); returns
        ``(reports, reclaimed)`` keyed by view name."""
        reports: Dict[str, object] = {}
        reclaimed: Dict[str, int] = {}
        if action == "compact":
            for name in list(self._views):
                slack = max(policy.slack_slabs,
                            self._last_reserve.get(name, 0))
                self._views[name], reports[name] = self._compact_view(
                    self._views[name], policy, shrink=shrink,
                    slack_slabs=slack)
        elif action == "reclaim":
            for name in list(self._views):
                self._views[name], reclaimed[name] = self._reclaim_view(
                    self._views[name])
        else:
            raise ValueError(f"unknown maintenance action {action!r}")
        return reports, reclaimed

    def _cheap_stats(self) -> dict:
        """O(1) stand-in for ``pool_stats`` covering the triggers that need
        no pool scan.  Tombstone accounting is EXACT (every recorded delete
        mints one tombstone; only maintenance clears them); the scan-only
        fields are pinned to never-trigger values — a policy enabling those
        triggers takes the full-scan path instead.
        """
        tombs = self._tombstone_base + self._deletes_since_maint
        live = int(self.n_edges)
        return {"tombstone_ratio": tombs / max(1, tombs + live),
                "tombstone_lanes": tombs,
                "mean_chain": 0.0, "occupancy": 1.0, "dead_slabs": 0}

    def _auto_maintain(self) -> None:
        """Epoch-close hook: count the epoch, run the policy if present."""
        self._epochs_since_maint += 1
        if self.maintenance is not None:
            self.maintain()

    def maintain(self, action: Optional[str] = None):
        """Run pool maintenance across every view as ONE versioned unit.

        With ``action=None`` the store's ``MaintenancePolicy`` decides —
        from O(1) delete accounting when only the tombstone/every triggers
        are armed, from a full forward-view ``pool_stats`` scan when a
        chain/occupancy/dead-slab trigger needs it — and no-ops (returns
        None) without a trigger, so the per-epoch policy check costs no
        device transfer in the common case.  ``action="compact"`` /
        ``"reclaim"`` forces that tier.  On action: all views maintain
        together, the store version bumps, and listeners see a
        ``maintenance=True`` AppliedBatch — property states survive
        (vertex ids are stable); slab handles retained from before are
        stale and must be re-resolved via the reports' ``perm``.  Returns
        the ``MaintenanceRecord``.
        """
        import time as _time

        from .maintenance import MaintenancePolicy, MaintenanceRecord

        policy = self.maintenance or MaintenancePolicy()
        needs_scan = bool(policy.max_mean_chain or policy.min_occupancy
                          or policy.reclaim_dead_slabs)
        trigger = "forced"
        if action is None:
            stats = self.pool_stats() if needs_scan else self._cheap_stats()
            decision = policy.decide(
                stats, epochs_since=self._epochs_since_maint)
            if decision is None:
                return None
            action, trigger = decision
            if not needs_scan:           # a trigger fired: scan for shrink
                stats = self.pool_stats()
        else:
            stats = self.pool_stats()
        t0 = _time.time()
        with obs.span("store.maintain", version=self.version,
                      action=action, trigger=trigger):
            reports, reclaimed = self._maintain_views(
                action, policy, shrink=policy.allow_shrink(stats))
        self._epochs_since_maint = 0
        self._deletes_since_maint = 0
        # compaction drops every tombstone; reclamation only frees wholly
        # dead slabs — keep the (pre-pass, thus conservative) count.
        self._tombstone_base = (0 if action == "compact"
                                else stats["tombstone_lanes"])
        batch = self._record_batch(
            ins_src=None, ins_dst=None, ins_w=None, ins_mask=None,
            del_src=None, del_dst=None, del_mask=None,
            n_inserted=0, n_deleted=0, maintenance=True)
        fwd_report = reports.get(FORWARD)
        record = MaintenanceRecord(
            version=batch.version, action=action, trigger=trigger,
            reports=reports, reclaimed=reclaimed,
            duration_s=_time.time() - t0,
            tombstone_ratio=float(stats["tombstone_ratio"]),
            capacity_before=(fwd_report.old_capacity if fwd_report
                             else int(stats.get("capacity_slabs", 0))),
            capacity_after=(fwd_report.new_capacity if fwd_report
                            else int(stats.get("capacity_slabs", 0))),
            slabs_reclaimed=sum(reclaimed.values()))
        self.maintenance_count += 1
        self.last_maintenance = record
        # the structured per-pass event stream (bounded like the batch log)
        self.maintenance_events.append(record.as_event())
        if len(self.maintenance_events) > self._log_capacity:
            self.maintenance_events = \
                self.maintenance_events[-self._log_capacity:]
        obs.emit_event("maintenance", **record.as_event())
        obs.inc(f"store.maintain.{action}")
        _flight.record(_FL_MAINTAIN, batch.version,
                       record.slabs_reclaimed, record.capacity_after)
        return record


class GraphStore(VersionedStoreBase):
    """Forward + transposed + symmetric SlabGraph views as one versioned unit."""

    def __init__(self, views: Dict[str, SlabGraph], *, weighted: bool,
                 version: int = 0, log_capacity: int = 64,
                 maintenance=None):
        assert FORWARD in views, "a GraphStore always carries the forward view"
        unknown = set(views) - set(ALL_VIEWS)
        assert not unknown, f"unknown views {unknown}"
        super().__init__(version=version, log_capacity=log_capacity,
                         maintenance=maintenance)
        self._views = dict(views)
        self.weighted = bool(weighted)
        self._max_bpv = int(np.max(np.asarray(
            views[FORWARD].bucket_count))) if views[FORWARD].n_vertices else 1

    # ------------------------------------------------------------- construct
    @classmethod
    def from_edges(cls, n_vertices: int, src, dst, w=None, *,
                   hashing: bool = False, load_factor: float = 0.7,
                   slack_slabs: int = 0, with_transpose: bool = True,
                   with_symmetric: bool = True,
                   log_capacity: int = 64,
                   maintenance=None) -> "GraphStore":
        """Bulk-build every view from one host edge list (dedup shared)."""
        src, dst, w = dedup_pairs(src, dst, w)
        kw = dict(hashing=hashing, load_factor=load_factor,
                  slack_slabs=slack_slabs)
        views = {FORWARD: from_edges_host(n_vertices, src, dst, w, **kw)}
        if with_transpose:
            views[TRANSPOSE] = from_edges_host(n_vertices, dst, src, w, **kw)
        if with_symmetric:
            s2 = np.concatenate([src, dst])
            d2 = np.concatenate([dst, src])
            w2 = None if w is None else np.concatenate([w, w])
            views[SYMMETRIC] = from_edges_host(n_vertices, s2, d2, w2, **kw)
        return cls(views, weighted=w is not None, log_capacity=log_capacity,
                   maintenance=maintenance)

    # ------------------------------------------------------------- accessors
    @property
    def forward(self) -> SlabGraph:
        return self._views[FORWARD]

    @property
    def transpose(self) -> Optional[SlabGraph]:
        return self._views.get(TRANSPOSE)

    @property
    def symmetric(self) -> Optional[SlabGraph]:
        return self._views.get(SYMMETRIC)

    @property
    def views(self) -> Dict[str, SlabGraph]:
        return dict(self._views)

    @property
    def n_vertices(self) -> int:
        return self.forward.n_vertices

    @property
    def n_edges(self) -> int:
        return int(self.forward.n_edges)

    @property
    def out_degree(self) -> jnp.ndarray:
        """Device-resident out-degrees — the forward view's ``degree`` field."""
        return self.forward.degree

    @property
    def in_degree(self) -> jnp.ndarray:
        if self.transpose is None:
            raise ValueError("in-degrees live on the transpose view; build "
                             "the store with with_transpose=True")
        return self.transpose.degree

    @property
    def max_bpv(self) -> int:
        return self._max_bpv

    # ----------------------------------------------------------------- apply
    def apply(self, ins_src=None, ins_dst=None, ins_w=None,
              del_src=None, del_dst=None) -> AppliedBatch:
        """Apply one mixed update batch to every view; close the epoch.

        Deletions apply first, then insertions.  The batch is deduped and
        padded exactly once (``canonical_batch``); all live views mutate
        through one donated ``update_views`` dispatch.  Weighted stores
        default missing insert weights to 1.0.  Returns the
        ``AppliedBatch`` record (also appended to the catch-up log).

        Resilience plane (DESIGN.md §11): the RAW inputs are validated at
        admission (``QuarantinedBatch`` on corruption — nothing moved),
        the canonical batch journals to the attached WAL (fsync) before
        the donated dispatch, capacity growth runs under the store's
        ``RetryBudget``, and every phase carries a named fault point.
        """
        # admission guard FIRST, on the raw inputs: canonical_batch's
        # uint32 casts would silently wrap a negative/float id
        validate_batch(ins_src, ins_dst, ins_w, del_src, del_dst,
                       n_vertices=self.n_vertices)
        t0 = time.perf_counter()
        epoch_span = obs.span("store.apply", version=self.version)
        epoch_span.__enter__()
        try:
            with obs.span("store.apply.host_dedup"):
                i_s, i_d, i_w, d_s, d_d = canonical_batch(
                    ins_src, ins_dst, ins_w, del_src, del_dst,
                    weighted=self.weighted)
            faults.fault_point("apply.admitted", version=self.version)
            _flight.record(_FL_ADMIT, self.version, len(i_s), len(d_s))

            roles = tuple(v for v in ALL_VIEWS if v in self._views)

            # -- capacity (inserts allocate at most one slab per lane) ------
            if len(i_s):
                with obs.span("store.apply.capacity"):
                    p = _pow2(len(i_s))

                    def _grow():
                        faults.fault_point("store.capacity_grow",
                                           version=self.version)
                        for name in roles:
                            need = (2 * p + 64 if name == SYMMETRIC
                                    else p + 64)
                            self._views[name] = ensure_capacity(
                                self._views[name], need)
                            self._last_reserve[name] = need
                        _flight.record(_FL_GROW, self.version, p)

                    run_with_retries(_grow, budget=self.retry,
                                     site="store.capacity_grow")

            # -- canonical device batches (every view derives from these) ---
            del_sj = del_dj = del_mask = None
            ins_sj = ins_dj = ins_wj = ins_mask = None
            dels = ins = None
            if len(d_s):
                p = _pow2(len(d_s))
                del_sj, del_dj = _pad_u32(d_s, p), _pad_u32(d_d, p)
                dels = (del_sj, del_dj)
            if len(i_s):
                p = _pow2(len(i_s))
                ins_sj, ins_dj = _pad_u32(i_s, p), _pad_u32(i_d, p)
                ins_wj = _pad_f32(i_w, p)
                ins = (ins_sj, ins_dj, ins_wj)

            # -- durability: journal the canonical batch, THEN dispatch -----
            wal_token = self._wal_append(i_s, i_d, i_w, d_s, d_d)
            faults.fault_point("apply.post_wal", version=self.version)
            _flight.record(_FL_POST_WAL, self.version,
                           0 if wal_token is None else 1)

            try:
                # -- single stacked engine dispatch over every live view ----
                n_inserted = n_deleted = 0
                if ins is not None or dels is not None:
                    with obs.span("store.apply.dispatch",
                                  version=self.version, views=len(roles)):
                        new_views, ins_mask, del_mask = update_views(
                            tuple(self._views[r] for r in roles), roles,
                            ins, dels)
                        for r, g in zip(roles, new_views):
                            self._views[r] = g
                        if del_mask is not None:
                            n_deleted = int(jnp.sum(
                                del_mask.astype(jnp.int32)))
                        if ins_mask is not None:
                            n_inserted = int(jnp.sum(
                                ins_mask.astype(jnp.int32)))
                faults.fault_point("apply.pre_close", version=self.version)
                _flight.record(_FL_DISPATCH, self.version,
                               n_inserted, n_deleted)

                # -- version bump + notification (epoch still open) ---------
                with obs.span("store.apply.notify"):
                    batch = self._record_batch(
                        ins_src=ins_sj, ins_dst=ins_dj, ins_w=ins_wj,
                        ins_mask=ins_mask, del_src=del_sj, del_dst=del_dj,
                        del_mask=del_mask,
                        n_inserted=n_inserted, n_deleted=n_deleted)

                # -- close the epoch on every view --------------------------
                with obs.span("store.apply.epoch_close",
                              sync=tuple(self._views.values())):
                    for name, g in self._views.items():
                        self._views[name] = update_slab_pointers(g)
                faults.fault_point("apply.post_close", version=self.version)
                _flight.record(_FL_CLOSE, batch.version,
                               n_inserted, n_deleted)
            except faults.InjectedCrash:
                raise          # a simulated kill: the WAL record survives
            except BaseException:
                # the journaled batch never applied in THIS process and the
                # caller sees the failure — drop the record so a later
                # recovery replay doesn't resurrect a rejected batch
                if wal_token is not None:
                    self.wal.rollback(wal_token)
                raise

            epoch_span.annotate(inserted=n_inserted, deleted=n_deleted)
        except BaseException as e:
            # the black box: dump a post-mortem bundle beside the WAL at
            # the moment of death (never raises, skips recoverable kinds)
            self._dump_postmortem(e)
            raise
        finally:
            epoch_span.__exit__(None, None, None)
        if obs.metrics.enabled():
            obs.observe("store.apply", time.perf_counter() - t0)
            obs.inc("store.apply.epochs")
            obs.inc("store.apply.inserted", n_inserted)
            obs.inc("store.apply.deleted", n_deleted)

        # -- maintenance + audit planes: policy checks on the closed epoch --
        self._auto_maintain()
        self._auto_audit()
        return batch

    # ----------------------------------------------------- maintenance plane
    def pool_stats(self, view: str = FORWARD) -> dict:
        """Pool-health snapshot of one view (``core.pool_stats``)."""
        from ..core.slab_graph import pool_stats as _pool_stats
        return _pool_stats(self._views[view])

    def _compact_view(self, g: SlabGraph, policy, *, shrink: bool,
                      slack_slabs: int):
        from ..kernels.slab_compact import compact
        return compact(g, impl=policy.impl, shrink=shrink,
                       slack_slabs=slack_slabs)

    def _reclaim_view(self, g: SlabGraph):
        from ..kernels.slab_compact import reclaim_free_slabs
        return reclaim_free_slabs(g)

    # --------------------------------------------------------------- queries
    def query(self, src, dst) -> np.ndarray:
        """Batched edge-membership against the forward view (host arrays in,
        host bool array out, trimmed to the query length)."""
        src = np.asarray(src, np.uint32)
        dst = np.asarray(dst, np.uint32)
        p = _pow2(max(len(src), 1))
        found = query_edges(self.forward, _pad_u32(src, p), _pad_u32(dst, p))
        return np.asarray(found)[:len(src)]

    def neighbors(self, vertices, *, out_capacity: int = 4096
                  ) -> EdgeFrontier:
        """Current out-edges of ``vertices`` (forward view) as an EdgeFrontier."""
        vertices = np.asarray(vertices, np.uint32)
        p = _pow2(max(len(vertices), 1))
        verts = _pad_u32(vertices, p)
        vmask = jnp.asarray(np.arange(p) < len(vertices))
        return expand_vertices(self.forward, verts, vmask,
                               out_capacity=_pow2(out_capacity),
                               max_bpv=self._max_bpv)

    # ------------------------------------------------------------ checkpoint
    def save(self, ckpt_dir, step: Optional[int] = None, *, registry=None,
             extra: Optional[dict] = None, keep_last: int = 3):
        """Persist all views (+ registered property states) atomically.

        The manifest's ``extra`` carries everything ``restore`` needs to
        rebuild the pytree structure: per-view bucket metadata, the store
        version, and per-property versions.
        """
        from ..checkpoint import ckpt
        step = self.version if step is None else int(step)
        props = {} if registry is None else registry.states()
        prop_versions = {} if registry is None else registry.versions()
        meta = {
            "stream_store": True,
            "version": int(self.version),
            "n_vertices": int(self.n_vertices),
            "weighted": bool(self.weighted),
            "views": {name: int(g.n_buckets)
                      for name, g in self._views.items()},
            "prop_versions": {k: int(v) for k, v in prop_versions.items()},
            "resilience": self._resilience_meta(),
        }
        if extra:
            meta.update(extra)
        path = ckpt.save(ckpt_dir, step, {"views": dict(self._views),
                                          "props": props}, extra=meta,
                         keep_last=keep_last)
        # the checkpoint now covers every journaled batch up to this
        # version: retire the WAL segments it subsumes
        if self.wal is not None and step == self.version:
            self.wal.truncate(self.version)
        return path

    @classmethod
    def restore(cls, ckpt_dir, *, step: Optional[int] = None,
                specs: Sequence = (), policies: Optional[Dict[str, str]] = None,
                log_capacity: int = 64, maintenance=None):
        """Rebuild (store, registry) from a checkpoint.

        ``specs`` must cover every property saved in the checkpoint (their
        ``state_like`` builds the restore skeleton; their maintainers resume
        from the saved states + versions).  Returns ``(store, registry)``;
        the registry is None when the checkpoint carried no properties and
        no specs were given.  ``maintenance=`` re-attaches the policy the
        crashed process ran — its trigger counters are restored from the
        manifest, so a WAL replay re-derives maintenance epochs exactly.
        """
        from ..checkpoint import ckpt
        from ..checkpoint.ckpt import CheckpointError
        manifest = ckpt.read_manifest(ckpt_dir, step=step)
        meta = manifest["extra"]
        missing = [k for k in ("n_vertices", "weighted", "views",
                               "prop_versions")
                   if not meta.get("stream_store") or k not in meta]
        if missing or not meta.get("stream_store"):
            raise CheckpointError(
                f"{ckpt_dir} step {manifest['step']} is not a GraphStore "
                f"checkpoint (missing meta: "
                f"{missing or ['stream_store']}) — it was saved by a "
                "different layer or its manifest is from an incompatible "
                "version; pick another step= or re-checkpoint")
        V = int(meta["n_vertices"])
        weighted = bool(meta["weighted"])

        def view_like(n_buckets: int) -> SlabGraph:
            bc = np.zeros(V, np.int32)
            bc[0] = n_buckets
            return empty(V, bc, n_buckets + 1, weighted=weighted)

        like_views = {name: view_like(nb)
                      for name, nb in meta["views"].items()}
        spec_by_name = {s.name: s for s in specs}
        like_props = {}
        for name in meta["prop_versions"]:
            if name not in spec_by_name:
                raise KeyError(
                    f"checkpoint stores property {name!r}; pass its "
                    f"PropertySpec via specs= to restore it")
            like_props[name] = spec_by_name[name].state_like(V)
        tree, _ = ckpt.restore(ckpt_dir, {"views": like_views,
                                          "props": like_props},
                               step=manifest["step"])
        store = cls(tree["views"], weighted=weighted,
                    version=meta["version"], log_capacity=log_capacity,
                    maintenance=maintenance)
        store._adopt_resilience_meta(meta)

        registry = None
        if spec_by_name:
            from .properties import PropertyRegistry
            registry = PropertyRegistry(store)
            policies = policies or {}
            for name, spec in spec_by_name.items():
                if name in tree["props"]:
                    registry.register(spec,
                                      policy=policies.get(name, "lazy"),
                                      _state=tree["props"][name],
                                      _version=meta["prop_versions"][name])
                else:
                    registry.register(spec, policy=policies.get(name, "lazy"))
        return store, registry
