"""Iteration primitives over the slab pool.

These are the TPU forms of Meerkat's three iterators (paper §3.4, Tables 1–3):

* ``pool_edges``        — SlabIterator over *all* vertices: the whole pool is
  one dense (S,128) array, so "iterate every slab of every vertex" is a single
  vectorised sweep with ``slab_vertex`` as the segment-id vector.  This is the
  generalisation of IterationScheme2's ⟨bucket_vertex, bucket_index⟩ work-list:
  the work items are slab rows, pre-flattened, load-balanced by construction.
* ``updated_lane_mask`` — UpdateIterator: an O(1)-state lane mask selecting
  exactly the entries inserted since the last ``update_slab_pointers()``.
* ``expand_vertices``   — IterationScheme1 for a *frontier*: walk the slab
  chains of a given vertex set and emit their current out-edges, with
  prefix-sum (ballot→popc) compaction into a fixed-capacity edge buffer.
* ``csr_snapshot``      — freeze the current adjacency into CSR (used to feed
  static baselines and the GNN configs that consume a graph snapshot).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import INVALID_SLAB, SLAB_WIDTH, is_valid_vertex
from .slab_graph import SlabGraph, from_edges_host


class PoolView(NamedTuple):
    """Dense view of every adjacency entry in the pool."""
    src: jnp.ndarray     # (S, 128) int32 — owner vertex per lane (-1 unalloc)
    dst: jnp.ndarray     # (S, 128) uint32 — neighbor ids (sentinels included)
    weight: Optional[jnp.ndarray]  # (S, 128) float32 or None
    valid: jnp.ndarray   # (S, 128) bool — allocated & holds a real neighbor


def pool_edges(g: SlabGraph) -> PoolView:
    """SlabIterator over all vertices as one dense sweep."""
    src = jnp.broadcast_to(g.slab_vertex[:, None],
                           (g.capacity_slabs, SLAB_WIDTH))
    valid = (g.slab_vertex[:, None] >= 0) & is_valid_vertex(g.keys)
    return PoolView(src=src, dst=g.keys, weight=g.weights, valid=valid)


def updated_lane_mask(g: SlabGraph) -> jnp.ndarray:
    """(S,128) bool — lanes holding edges inserted in the current epoch.

    Rule 1: slabs allocated this epoch (``slab_new`` — set by insert
            placement, cleared by ``update_slab_pointers``) are wholly new.
            A row-id compare against ``epoch_next_free`` is no longer
            equivalent: the free-slab recycling list hands out reclaimed
            slabs *below* the bump-allocator watermark.
    Rule 2: a flagged bucket's ``upd_slab`` is new from ``upd_lane`` onward
            (Fig. 2: the old tail slab, partially old).
    Everything later in a flagged chain is covered by rule 1 because inserts
    append at the tail.
    """
    S = g.capacity_slabs
    start = jnp.where(g.slab_new, 0, SLAB_WIDTH)                # (S,)
    flagged = g.upd_flag & ~g.slab_new[g.upd_slab]
    tgt = jnp.where(flagged, g.upd_slab, S)  # park non-flagged OOB
    start = start.at[tgt].min(jnp.where(flagged, g.upd_lane, SLAB_WIDTH),
                              mode="drop")
    lane = jnp.arange(SLAB_WIDTH, dtype=jnp.int32)
    mask = lane[None, :] >= start[:, None]
    return mask & (g.slab_vertex[:, None] >= 0) & is_valid_vertex(g.keys)


@partial(jax.jit, static_argnames=("max_buckets", "out_capacity"))
def updated_edges(g: SlabGraph, *, max_buckets: int,
                  out_capacity: int) -> "EdgeFrontier":
    """True UpdateIterator traversal: O(#updated slabs), not O(pool).

    Compacts the flagged buckets, then chain-walks from each bucket's
    (upd_slab, upd_lane) emitting only this epoch's lanes — the paper's
    'visit only those slabs holding new adjacent vertices', with the first
    partially-old slab handled by the stored lane offset (Fig. 2).
    ``max_buckets`` bounds flagged buckets, ``out_capacity`` the emitted
    edges (≈ batch size); overflow is flagged.
    """
    m = g.upd_flag.astype(jnp.int32)
    pos = jnp.cumsum(m) - m
    idx = jnp.where(g.upd_flag & (pos < max_buckets), pos, max_buckets)
    bids = jnp.zeros((max_buckets,), jnp.int32).at[idx].set(
        jnp.arange(g.n_buckets, dtype=jnp.int32), mode="drop")
    nb = jnp.minimum(jnp.sum(m), max_buckets)
    bmask = jnp.arange(max_buckets) < nb

    cur = jnp.where(bmask, g.upd_slab[jnp.minimum(bids, g.n_buckets - 1)],
                    INVALID_SLAB).astype(jnp.int32)
    lane_min = jnp.where(bmask,
                         g.upd_lane[jnp.minimum(bids, g.n_buckets - 1)], 0)

    cap = out_capacity
    buf_src = jnp.zeros((cap,), dtype=jnp.uint32)
    buf_dst = jnp.zeros((cap,), dtype=jnp.uint32)
    buf_w = jnp.zeros((cap,), dtype=jnp.float32)
    size = jnp.asarray(0, jnp.int32)
    lane = jnp.arange(SLAB_WIDTH, dtype=jnp.int32)

    def cond(state):
        return jnp.any(state[0] != INVALID_SLAB)

    def body(state):
        cur, lane_min, bsrc, bdst, bw, size = state
        active = cur != INVALID_SLAB
        rows = g.keys[jnp.maximum(cur, 0)]
        owners = g.slab_vertex[jnp.maximum(cur, 0)]
        emit = active[:, None] & is_valid_vertex(rows) \
            & (lane[None, :] >= lane_min[:, None])
        flat = emit.reshape(-1)
        p = size + jnp.cumsum(flat.astype(jnp.int32)) - flat.astype(jnp.int32)
        widx = jnp.where(flat, p, cap)
        bsrc = bsrc.at[widx].set(
            jnp.broadcast_to(owners[:, None].astype(jnp.uint32),
                             rows.shape).reshape(-1), mode="drop")
        bdst = bdst.at[widx].set(rows.reshape(-1), mode="drop")
        if g.weighted:
            bw = bw.at[widx].set(
                g.weights[jnp.maximum(cur, 0)].reshape(-1), mode="drop")
        size = size + jnp.sum(flat.astype(jnp.int32))
        cur = jnp.where(active, g.next_slab[jnp.maximum(cur, 0)],
                        INVALID_SLAB)
        lane_min = jnp.zeros_like(lane_min)  # later slabs are wholly new
        return cur, lane_min, bsrc, bdst, bw, size

    _, _, buf_src, buf_dst, buf_w, size = jax.lax.while_loop(
        cond, body, (cur, lane_min, buf_src, buf_dst, buf_w, size))
    return EdgeFrontier(src=buf_src, dst=buf_dst, weight=buf_w,
                        size=jnp.minimum(size, cap), overflow=size > cap)


def updated_vertices(g: SlabGraph) -> jnp.ndarray:
    """(V,) bool — the per-vertex ``is_updated`` flag of the SlabIterator
    incremental scheme (paper §6.4.2): vertex has ≥1 flagged bucket."""
    per_vertex = jax.ops.segment_max(
        g.upd_flag.astype(jnp.int32), g.bucket_vertex,
        num_segments=g.n_vertices)
    return per_vertex > 0


class EdgeFrontier(NamedTuple):
    src: jnp.ndarray      # (cap,) uint32
    dst: jnp.ndarray      # (cap,) uint32
    weight: jnp.ndarray   # (cap,) float32 (zeros when unweighted)
    size: jnp.ndarray     # () int32
    overflow: jnp.ndarray # () bool


@partial(jax.jit, static_argnames=("out_capacity", "max_bpv"))
def expand_vertices(g: SlabGraph, verts: jnp.ndarray, vmask: jnp.ndarray,
                    *, out_capacity: int, max_bpv: int = 1) -> EdgeFrontier:
    """Emit the current out-edges of ``verts`` (masked by ``vmask``).

    ``max_bpv`` must bound max(bucket_count) (1 when hashing is disabled —
    the configuration the paper uses for BFS/SSSP/PageRank).  The chain walk
    is a ``while_loop`` whose body touches one slab row per active bucket —
    the direct analogue of a warp advancing its SlabIterator.
    """
    Nv = verts.shape[0]
    v = jnp.where(vmask, verts, 0).astype(jnp.int32)
    j = jnp.arange(max_bpv, dtype=jnp.int32)[None, :]
    bmask = vmask[:, None] & (j < g.bucket_count[v][:, None])
    buckets = (g.bucket_offset[v][:, None] + j).reshape(-1)
    bmask = bmask.reshape(-1)
    cur = jnp.where(bmask, buckets, INVALID_SLAB).astype(jnp.int32)

    cap = out_capacity
    buf_src = jnp.zeros((cap,), dtype=jnp.uint32)
    buf_dst = jnp.zeros((cap,), dtype=jnp.uint32)
    buf_w = jnp.zeros((cap,), dtype=jnp.float32)
    size = jnp.asarray(0, jnp.int32)

    def cond(state):
        cur = state[0]
        return jnp.any(cur != INVALID_SLAB)

    def body(state):
        cur, bsrc, bdst, bw, size = state
        active = cur != INVALID_SLAB
        rows = g.keys[jnp.maximum(cur, 0)]                      # (Nb,128)
        owners = g.slab_vertex[jnp.maximum(cur, 0)]             # (Nb,)
        emit = active[:, None] & is_valid_vertex(rows)
        flat = emit.reshape(-1)
        pos = size + jnp.cumsum(flat.astype(jnp.int32)) - flat.astype(jnp.int32)
        idx = jnp.where(flat, pos, cap)  # OOB drop for non-emitting lanes
        bsrc = bsrc.at[idx].set(
            jnp.broadcast_to(owners[:, None].astype(jnp.uint32),
                             rows.shape).reshape(-1), mode="drop")
        bdst = bdst.at[idx].set(rows.reshape(-1), mode="drop")
        if g.weighted:
            wrow = g.weights[jnp.maximum(cur, 0)].reshape(-1)
            bw = bw.at[idx].set(wrow, mode="drop")
        size = size + jnp.sum(flat.astype(jnp.int32))
        cur = jnp.where(active, g.next_slab[jnp.maximum(cur, 0)], INVALID_SLAB)
        return cur, bsrc, bdst, bw, size

    _, buf_src, buf_dst, buf_w, size = jax.lax.while_loop(
        cond, body, (cur, buf_src, buf_dst, buf_w, size))
    return EdgeFrontier(src=buf_src, dst=buf_dst, weight=buf_w,
                        size=jnp.minimum(size, cap),
                        overflow=size > cap)


class CSR(NamedTuple):
    indptr: jnp.ndarray   # (V+1,) int32
    indices: jnp.ndarray  # (E_cap,) int32 (padded with -1)
    weights: Optional[jnp.ndarray]
    n_edges: jnp.ndarray  # () int32


@partial(jax.jit, static_argnames=("max_edges",))
def csr_snapshot(g: SlabGraph, *, max_edges: int) -> CSR:
    """Freeze the dynamic structure into CSR (sorted by source vertex)."""
    view = pool_edges(g)
    flat_src = jnp.where(view.valid, view.src, g.n_vertices).reshape(-1)
    flat_dst = view.dst.reshape(-1)
    flat_w = (view.weight.reshape(-1) if view.weight is not None else None)
    order = jnp.argsort(flat_src, stable=True)
    s = flat_src[order]
    d = flat_dst[order]
    n_e = jnp.sum(view.valid.astype(jnp.int32))
    counts = jax.ops.segment_sum(
        jnp.ones_like(s), s, num_segments=g.n_vertices + 1)[:g.n_vertices]
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    take = min(max_edges, s.shape[0])
    indices = jnp.where(jnp.arange(take) < n_e,
                        d[:take].astype(jnp.int32), -1)
    w = None
    if flat_w is not None:
        w = jnp.where(jnp.arange(take) < n_e, flat_w[order][:take], 0.0)
    return CSR(indptr=indptr, indices=indices, weights=w, n_edges=n_e)


def transpose_host(g: SlabGraph, *, symmetric: bool = False,
                   hashing: bool = False, load_factor: float = 0.7,
                   slack_slabs: int = 0) -> SlabGraph:
    """Host-side transpose: the in-edge SlabGraph of ``g`` (owner = dst,
    lane keys = src), weights carried along.

    The slab-sweep engine reduces into the slab *owner* (pull direction), so
    push-style relaxations (BFS levels, SSSP waves over out-edge storage)
    run their sweeps on this transposed view — the same layout PageRank
    already stores natively.  ``symmetric=True`` keeps both directions
    (the undirected view WCC label propagation needs).  Host-side by design:
    rebuilt between update epochs, like ``ensure_capacity``.
    """
    view = pool_edges(g)
    valid = np.asarray(view.valid)
    src = np.asarray(view.src)[valid].astype(np.uint32)
    dst = np.asarray(view.dst)[valid].astype(np.uint32)
    w = np.asarray(view.weight)[valid] if g.weighted else None
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if w is not None:
            w = np.concatenate([w, w])
        return from_edges_host(g.n_vertices, src, dst, w, hashing=hashing,
                               load_factor=load_factor,
                               slack_slabs=slack_slabs)
    return from_edges_host(g.n_vertices, dst, src, w, hashing=hashing,
                           load_factor=load_factor, slack_slabs=slack_slabs)


def occupancy_stats(g: SlabGraph) -> dict:
    """Slab occupancy / allocation stats (memory table + paper §6.1 claims)."""
    view = pool_edges(g)
    alloc = g.slab_vertex >= 0
    n_alloc = jnp.sum(alloc.astype(jnp.int32))
    used_lanes = jnp.sum(view.valid.astype(jnp.int32))
    return {
        "allocated_slabs": int(n_alloc),
        "capacity_slabs": g.capacity_slabs,
        "used_lanes": int(used_lanes),
        "occupancy": float(used_lanes) / float(max(1, int(n_alloc)) * SLAB_WIDTH),
        "pool_bytes": int(g.keys.size * 4 +
                          (g.weights.size * 4 if g.weighted else 0)),
        "repr_bytes": g.nbytes(),
    }
