"""Meerkat-on-TPU core: pooled slab-hash dynamic graph + iteration primitives.

The paper's primary contribution (dynamic graph representation, pooled
allocation, iterator set, warp-level APIs as lane-vector ops) lives here.
"""
from .hashing import (EMPTY_KEY, INVALID_LANE, INVALID_SLAB, INVALID_VERTEX,
                      SLAB_WIDTH, TOMBSTONE_KEY, bucket_hash, is_valid_vertex)
from .slab_graph import (SlabGraph, empty, ensure_capacity, from_edges_host,
                         next_pow2, plan_buckets, pool_stats,
                         update_slab_pointers)
from .batch import (apply_update, delete_edges, insert_edges, query_edges,
                    probe, update_views)
from .worklist import (CSR, EdgeFrontier, PoolView, csr_snapshot,
                       expand_vertices, occupancy_stats, pool_edges,
                       transpose_host, updated_lane_mask, updated_vertices)
from .frontier import Frontier, clear, enqueue, make_frontier, swap
from .union_find import (component_labels, compress, count_components, find,
                         init_parents, union_batch)
from .iterators import bucket_iterator, slab_iterator, update_iterator

__all__ = [
    "EMPTY_KEY", "INVALID_LANE", "INVALID_SLAB", "INVALID_VERTEX",
    "SLAB_WIDTH", "TOMBSTONE_KEY", "bucket_hash", "is_valid_vertex",
    "SlabGraph", "empty", "ensure_capacity", "from_edges_host",
    "next_pow2", "plan_buckets", "pool_stats", "update_slab_pointers",
    "apply_update", "delete_edges", "insert_edges", "query_edges", "probe",
    "update_views",
    "CSR", "EdgeFrontier", "PoolView", "csr_snapshot", "expand_vertices",
    "occupancy_stats", "pool_edges", "transpose_host", "updated_lane_mask",
    "updated_vertices",
    "Frontier", "clear", "enqueue", "make_frontier", "swap",
    "component_labels", "compress", "count_components", "find",
    "init_parents", "union_batch",
    "bucket_iterator", "slab_iterator", "update_iterator",
]
