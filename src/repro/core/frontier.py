"""Frontier<T> — fixed-capacity work queue with prefix-sum enqueue.

``warpenqueuefrontier`` (paper Alg. 2) is ballot → popc → one aggregated
atomicAdd → per-lane positional write.  On TPU the ballot/popc pair *is* an
exclusive prefix sum over the participation mask, and the atomic base counter
is the carried ``size`` scalar — so the whole operation becomes deterministic
masked compaction.  Capacity is static (compile-time); overflow is detected
and surfaced, the host grows the buffer between steps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["data", "size", "overflow"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Frontier:
    data: jnp.ndarray      # (cap, k) — k fields per element (e.g. src,dst,w)
    size: jnp.ndarray      # () int32
    overflow: jnp.ndarray  # () bool

    @property
    def capacity(self) -> int:
        return self.data.shape[0]


def make_frontier(capacity: int, n_fields: int,
                  dtype=jnp.float32) -> Frontier:
    return Frontier(data=jnp.zeros((capacity, n_fields), dtype=dtype),
                    size=jnp.asarray(0, jnp.int32),
                    overflow=jnp.asarray(False))


def clear(f: Frontier) -> Frontier:
    return dataclasses.replace(f, size=jnp.asarray(0, jnp.int32),
                               overflow=jnp.asarray(False))


def enqueue(f: Frontier, values: jnp.ndarray,
            mask: jnp.ndarray) -> Frontier:
    """Append ``values[mask]`` — the warpenqueuefrontier analogue.

    values: (n, k); mask: (n,) bool.  Writes past capacity are dropped and
    flagged.  The ``cumsum`` plays ballot+popc; ``size`` plays the aggregated
    atomic base.
    """
    m = mask.astype(jnp.int32)
    pos = f.size + jnp.cumsum(m) - m
    idx = jnp.where(mask & (pos < f.capacity), pos, f.capacity)
    data = f.data.at[idx].set(values.astype(f.data.dtype), mode="drop")
    new_size = f.size + jnp.sum(m)
    return Frontier(data=data,
                    size=jnp.minimum(new_size, f.capacity),
                    overflow=f.overflow | (new_size > f.capacity))


def swap(a: Frontier, b: Frontier) -> Tuple[Frontier, Frontier]:
    """Paper's ``swap(F_current, F_next)``; returns (new_current, cleared_next)."""
    return b, clear(a)
