"""Batched edge insert / delete / query on the SlabGraph.

GPU Meerkat mutates the structure with warp-cooperative lock-free CAS inside
a kernel.  The TPU translation (DESIGN.md §2) replaces concurrent insertion
with a deterministic *sort + prefix-scan placement*; as of the slab-update
engine (DESIGN.md §6) that pipeline is a first-class fused kernel plane in
``kernels/slab_update``:

* ``ops.py``   — the engine these entry points dispatch to: run-local
  O(batch) placement planning, a tiled Pallas chain-walk probe with
  per-tile termination, fused placement/tombstone commit, and optional
  buffer donation for in-place pool mutation (``donate=True`` /
  ``apply_update`` for the fused mixed delete+insert epoch).
* ``ref.py``   — the original whole-pool jnp path, kept verbatim as the
  bit-exact oracle (``impl="oracle"``) and interpret-mode fallback.

Everything is shape-static, so each batch size compiles once, and results
are bit-deterministic — a straight upgrade over atomics for reproducible
training pipelines.  All entry points accept padded batches (pad src with
INVALID_VERTEX); lanes with out-of-range src or sentinel dst are rejected
up front instead of probing with a garbage key.
"""
from __future__ import annotations

# Engine entry points (jit'd in ops.py; accept impl=/donate= kwargs).
from ..kernels.slab_update.ops import (apply_update, delete_edges,
                                       insert_edges, query_edges,
                                       query_shards, update_shards,
                                       update_views)
# Shared building blocks — the probe/hash helpers other layers reuse
# (triangle counting, slab_intersect) and the bit-exact oracle path.
from ..kernels.slab_update.ref import (batch_valid, delete_edges_ref,
                                       edge_buckets, insert_edges_ref, probe,
                                       query_edges_ref, sort_by_bucket)

# Backwards-compatible alias (pre-engine private name).
_sort_by_bucket = sort_by_bucket

__all__ = [
    "apply_update", "delete_edges", "insert_edges", "query_edges",
    "query_shards", "update_shards", "update_views",
    "batch_valid", "edge_buckets", "probe", "sort_by_bucket",
    "delete_edges_ref", "insert_edges_ref", "query_edges_ref",
]
