"""Union-Find for (incremental) weakly connected components.

The paper (§3.3.1, §4.4) uses UNION-ASYNC hooking with full path compression.
On TPU, lock-free CAS hooking becomes a *batch* union: repeatedly hook the
larger root under the smaller via a min-scatter (deterministic resolution of
concurrent unions), then pointer-jump (full path compression as vectorised
pointer doubling) until every vertex points at its root.  Each round is a
handful of gathers/scatters over (V,) arrays — ideal VPU work — and converges
in O(log V) rounds.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def init_parents(n: int) -> jnp.ndarray:
    return jnp.arange(n, dtype=jnp.int32)


def compress(parent: jnp.ndarray) -> jnp.ndarray:
    """Full path compression via pointer doubling: parent <- parent[parent]
    until fixpoint.  O(log depth) gathers."""
    def cond(p):
        return jnp.any(p != p[p])

    def body(p):
        return p[p]

    return jax.lax.while_loop(cond, body, parent)


def find(parent: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Roots for a batch of vertices; assumes ``parent`` is compressed."""
    return parent[v]


@jax.jit
def union_batch(parent: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray,
                mask: jnp.ndarray) -> jnp.ndarray:
    """UNION-ASYNC over an edge batch: hook max-root under min-root until no
    edge connects two distinct roots.  Deterministic: conflicting hooks on a
    root resolve by scatter-min."""
    parent = compress(parent)

    def cond(state):
        parent, active = state
        return jnp.any(active)

    def body(state):
        parent, active = state
        ru = parent[jnp.where(mask, u, 0)]
        rv = parent[jnp.where(mask, v, 0)]
        differs = mask & (ru != rv) & active
        hi = jnp.maximum(ru, rv)
        lo = jnp.minimum(ru, rv)
        tgt = jnp.where(differs, hi, parent.shape[0])  # OOB drop
        parent = parent.at[tgt].min(lo, mode="drop")
        parent = compress(parent)
        ru2 = parent[jnp.where(mask, u, 0)]
        rv2 = parent[jnp.where(mask, v, 0)]
        return parent, mask & (ru2 != rv2)

    active0 = mask & (parent[jnp.where(mask, u, 0)] !=
                      parent[jnp.where(mask, v, 0)])
    parent, _ = jax.lax.while_loop(cond, body, (parent, active0))
    return parent


def component_labels(parent: jnp.ndarray) -> jnp.ndarray:
    """Representative (min-id root) per vertex after compression."""
    return compress(parent)


def count_components(parent: jnp.ndarray) -> jnp.ndarray:
    p = compress(parent)
    return jnp.sum((p == jnp.arange(p.shape[0], dtype=p.dtype))
                   .astype(jnp.int32))
