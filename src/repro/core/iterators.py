"""Meerkat iterator API (paper Tables 1–3), expressed functionally.

The vectorised forms live in ``worklist.py`` (pool sweeps / frontier
expansion); this module provides the per-vertex iterator API for library users
and tests: ``slab_iterator`` walks every slab list of a vertex (SlabIterator),
``bucket_iterator`` walks one slab list (BucketIterator), ``update_iterator``
visits only the slabs holding this epoch's inserts (UpdateIterator).  Each
returns the visited neighbor ids as a fixed-capacity masked array — the JAX
rendering of "a warp advances the iterator one slab per step".
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .hashing import INVALID_SLAB, SLAB_WIDTH, is_valid_vertex
from .slab_graph import SlabGraph
from .worklist import updated_lane_mask


@partial(jax.jit, static_argnames=("max_neighbors",))
def bucket_iterator(g: SlabGraph, v: jnp.ndarray, bucket_index: jnp.ndarray,
                    *, max_neighbors: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """begin_at(i)/end_at(i): neighbors stored in vertex v's i'th slab list.

    Returns (neighbors[max_neighbors] uint32, count).  Slots past count are
    undefined (padded EMPTY).
    """
    b = g.bucket_offset[v] + bucket_index
    buf = jnp.full((max_neighbors,), jnp.uint32(0xFFFFFFFE), dtype=jnp.uint32)

    def cond(state):
        cur, _, _ = state
        return cur != INVALID_SLAB

    def body(state):
        cur, buf, n = state
        row = g.keys[cur]
        ok = is_valid_vertex(row)
        m = ok.astype(jnp.int32)
        pos = n + jnp.cumsum(m) - m
        idx = jnp.where(ok & (pos < max_neighbors), pos, max_neighbors)
        buf = buf.at[idx].set(row, mode="drop")
        return g.next_slab[cur], buf, n + jnp.sum(m)

    _, buf, n = jax.lax.while_loop(
        cond, body, (b.astype(jnp.int32), buf, jnp.asarray(0, jnp.int32)))
    return buf, jnp.minimum(n, max_neighbors)


@partial(jax.jit, static_argnames=("max_neighbors", "max_bpv"))
def slab_iterator(g: SlabGraph, v: jnp.ndarray, *, max_neighbors: int,
                  max_bpv: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """begin()/end(): all current neighbors of v, one slab list at a time."""
    buf = jnp.full((max_neighbors,), jnp.uint32(0xFFFFFFFE), dtype=jnp.uint32)
    n = jnp.asarray(0, jnp.int32)

    def per_bucket(i, carry):
        buf, n = carry
        nb, cnt = bucket_iterator(g, v, i, max_neighbors=max_neighbors)
        take = jnp.arange(max_neighbors, dtype=jnp.int32)
        ok = (take < cnt) & (i < g.bucket_count[v])
        pos = n + jnp.where(ok, take, 0)
        idx = jnp.where(ok & (pos < max_neighbors), pos, max_neighbors)
        buf = buf.at[idx].set(nb, mode="drop")
        n = n + jnp.where(i < g.bucket_count[v], cnt, 0)
        return buf, n

    buf, n = jax.lax.fori_loop(0, max_bpv, per_bucket, (buf, n))
    return buf, jnp.minimum(n, max_neighbors)


@partial(jax.jit, static_argnames=("max_neighbors",))
def update_iterator(g: SlabGraph, v: jnp.ndarray, *, max_neighbors: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """update_begin()/update_end(): only neighbors inserted this epoch."""
    mask = updated_lane_mask(g)                 # (S,128)
    mine = mask & (g.slab_vertex[:, None] == v.astype(jnp.int32))
    flat = mine.reshape(-1)
    keys = g.keys.reshape(-1)
    m = flat.astype(jnp.int32)
    pos = jnp.cumsum(m) - m
    idx = jnp.where(flat & (pos < max_neighbors), pos, max_neighbors)
    buf = jnp.full((max_neighbors,), jnp.uint32(0xFFFFFFFE), dtype=jnp.uint32)
    buf = buf.at[idx].set(keys, mode="drop")
    return buf, jnp.minimum(jnp.sum(m), max_neighbors)
