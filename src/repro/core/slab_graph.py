"""SlabGraph — Meerkat's pooled, hash-bucketed dynamic adjacency on TPU.

The GPU original keeps, per vertex, a SlabHash table whose buckets are linked
lists of 128-byte slabs, with *all* head slabs carved out of one pooled
allocation (the paper's memory-management contribution, Table 5).  The TPU/JAX
translation keeps the exact same object model but as a struct-of-arrays pytree:

  * one key pool        ``keys      : (capacity_slabs, 128) uint32``
  * one weight pool     ``weights   : (capacity_slabs, 128) float32`` (weighted)
  * chain "pointers"    ``next_slab : (capacity_slabs,) int32`` (-1 = end)
  * slab ownership      ``slab_vertex : (capacity_slabs,) int32`` — the
    materialised form of IterationScheme2's ⟨bucket_vertex⟩ vector
  * per-vertex bucket ranges via ``bucket_offset`` (exclusive scan of
    ``bucket_count`` — verbatim the paper's head-slab placement)
  * head slab of global bucket ``b`` is pool row ``b`` (head slabs occupy the
    pool prefix, one pooled allocation)
  * O(1) append state per bucket (``tail_slab`` / ``tail_fill``)
  * UpdateIterator state per bucket (``upd_flag`` / ``upd_slab`` / ``upd_lane``)
    plus ``epoch_next_free`` — every slab allocated after the last
    ``update_slab_pointers()`` is wholly "new"
  * a functional bump allocator (``next_free``) fronted by a free-slab
    recycling list (``free_list`` / ``free_top``) — the SlabAlloc reuse
    analogue: slabs reclaimed by the maintenance plane
    (``kernels/slab_compact``) are handed back to insert placement before
    the bump pointer advances
  * ``slab_new`` — per-slab "allocated this epoch" flag consumed by the
    UpdateIterator lane mask (recycled slabs sit below the old
    ``epoch_next_free`` watermark, so a bare row-id compare can no longer
    tell new slabs from old ones)

Everything is fixed-capacity inside jit; ``ensure_capacity`` (host side) grows
the pool between steps, mirroring the role of SlabAlloc's pre-allocated pool;
``kernels/slab_compact`` compacts and shrinks it back down under churn.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import (EMPTY_KEY, INVALID_SLAB, SLAB_WIDTH, TOMBSTONE_KEY)


@partial(jax.tree_util.register_dataclass,
         data_fields=["keys", "weights", "next_slab", "slab_vertex",
                      "bucket_offset", "bucket_count", "bucket_vertex",
                      "tail_slab", "tail_fill",
                      "upd_flag", "upd_slab", "upd_lane",
                      "next_free", "epoch_next_free",
                      "free_list", "free_top", "slab_new",
                      "degree", "n_edges"],
         meta_fields=["n_vertices", "n_buckets", "weighted"])
@dataclasses.dataclass(frozen=True)
class SlabGraph:
    # --- pools -------------------------------------------------------------
    keys: jnp.ndarray            # (S, 128) uint32, EMPTY/TOMBSTONE sentinels
    weights: Optional[jnp.ndarray]  # (S, 128) float32 or None
    next_slab: jnp.ndarray       # (S,) int32; -1 terminates a slab list
    slab_vertex: jnp.ndarray     # (S,) int32; owner vertex, -1 = unallocated
    # --- per-vertex bucket layout (paper: exclusive_scan(bucket_count)) -----
    bucket_offset: jnp.ndarray   # (V+1,) int32
    bucket_count: jnp.ndarray    # (V,) int32
    bucket_vertex: jnp.ndarray   # (B,) int32 — global bucket -> owner vertex
    # --- O(1) append state ---------------------------------------------------
    tail_slab: jnp.ndarray       # (B,) int32
    tail_fill: jnp.ndarray       # (B,) int32 in [0, 128]
    # --- UpdateIterator state (paper §3.4, Fig. 2) ---------------------------
    upd_flag: jnp.ndarray        # (B,) bool — bucket received inserts this epoch
    upd_slab: jnp.ndarray        # (B,) int32 — first slab holding new edges
    upd_lane: jnp.ndarray        # (B,) int32 — first new lane within upd_slab
    # --- allocator -----------------------------------------------------------
    next_free: jnp.ndarray       # () int32 — bump pointer into the pool
    epoch_next_free: jnp.ndarray # () int32 — next_free at last update_slab_pointers
    # --- free-slab recycling (SlabAlloc's reuse list, fed by maintenance) -----
    free_list: jnp.ndarray       # (S,) int32 — reclaimed slab ids in [0, free_top)
    free_top: jnp.ndarray        # () int32 — live length of free_list
    slab_new: jnp.ndarray        # (S,) bool — slab was (re)allocated this epoch
    # --- bookkeeping ----------------------------------------------------------
    degree: jnp.ndarray          # (V,) int32 — current stored-adjacency degree
    n_edges: jnp.ndarray         # () int32
    # --- static metadata -------------------------------------------------------
    n_vertices: int
    n_buckets: int
    weighted: bool

    # ------------------------------------------------------------------ props
    @property
    def capacity_slabs(self) -> int:
        return self.keys.shape[0]

    def nbytes(self) -> int:
        """Device bytes held by the representation (Table 5 accounting)."""
        total = 0
        for leaf in jax.tree_util.tree_leaves(self):
            total += leaf.size * leaf.dtype.itemsize
        return int(total)


# ============================================================================
# Construction
# ============================================================================

def plan_buckets(n_vertices: int, init_degree: np.ndarray, *,
                 load_factor: float = 0.7, hashing: bool = True) -> np.ndarray:
    """Paper §3.1: #head-slabs per vertex from initial degree and load factor.

    With hashing disabled every vertex gets exactly one slab list (the
    "single bucket" mode that improves slab occupancy for traversal-bound
    algorithms — paper §6.1).
    """
    if not hashing:
        return np.ones(n_vertices, dtype=np.int32)
    per_slab = SLAB_WIDTH * load_factor
    return np.maximum(1, np.ceil(init_degree / per_slab)).astype(np.int32)


def empty(n_vertices: int, bucket_count: np.ndarray, capacity_slabs: int, *,
          weighted: bool = False) -> SlabGraph:
    """Allocate an empty graph: the single pooled allocation of head slabs.

    Head slab of global bucket ``b`` is pool row ``b``; overflow slabs are bump
    allocated from row ``n_buckets`` upward.
    """
    bucket_count = np.asarray(bucket_count, dtype=np.int32)
    assert bucket_count.shape == (n_vertices,)
    bucket_offset = np.zeros(n_vertices + 1, dtype=np.int32)
    np.cumsum(bucket_count, out=bucket_offset[1:])
    n_buckets = int(bucket_offset[-1])
    capacity_slabs = int(max(capacity_slabs, n_buckets + 1))
    bucket_vertex = np.repeat(np.arange(n_vertices, dtype=np.int32), bucket_count)

    slab_vertex = np.full(capacity_slabs, -1, dtype=np.int32)
    slab_vertex[:n_buckets] = bucket_vertex

    return SlabGraph(
        keys=jnp.full((capacity_slabs, SLAB_WIDTH), EMPTY_KEY, dtype=jnp.uint32),
        weights=(jnp.zeros((capacity_slabs, SLAB_WIDTH), dtype=jnp.float32)
                 if weighted else None),
        next_slab=jnp.full((capacity_slabs,), INVALID_SLAB, dtype=jnp.int32),
        slab_vertex=jnp.asarray(slab_vertex),
        bucket_offset=jnp.asarray(bucket_offset),
        bucket_count=jnp.asarray(bucket_count),
        bucket_vertex=jnp.asarray(bucket_vertex),
        tail_slab=jnp.arange(n_buckets, dtype=jnp.int32),
        tail_fill=jnp.zeros((n_buckets,), dtype=jnp.int32),
        upd_flag=jnp.zeros((n_buckets,), dtype=bool),
        upd_slab=jnp.arange(n_buckets, dtype=jnp.int32),
        upd_lane=jnp.zeros((n_buckets,), dtype=jnp.int32),
        next_free=jnp.asarray(n_buckets, dtype=jnp.int32),
        epoch_next_free=jnp.asarray(n_buckets, dtype=jnp.int32),
        free_list=jnp.full((capacity_slabs,), INVALID_SLAB, dtype=jnp.int32),
        free_top=jnp.asarray(0, dtype=jnp.int32),
        slab_new=jnp.zeros((capacity_slabs,), dtype=bool),
        degree=jnp.zeros((n_vertices,), dtype=jnp.int32),
        n_edges=jnp.asarray(0, dtype=jnp.int32),
        n_vertices=n_vertices,
        n_buckets=n_buckets,
        weighted=weighted,
    )


def next_pow2(n: int, lo: int = 64) -> int:
    """Smallest power of two ≥ max(n, lo)."""
    return 1 << max(int(n) - 1, lo - 1, 1).bit_length()


def ensure_capacity(g: SlabGraph, extra_slabs: int) -> SlabGraph:
    """Host-side pool growth (outside jit) — the SlabAlloc re-pool analogue.

    Guarantees at least ``extra_slabs`` allocatable slabs.  Recycled slabs
    on the free list count toward that budget (insert placement drains the
    free list before bumping ``next_free``), so a churn-maintained pool can
    absorb batches without growing at all.  Grown capacities are quantized
    to powers of two (and grow by ≥ 1.5× so the amortised cost matches GPU
    pool allocators): a stream of update batches walks a small ladder of
    pool shapes instead of retriggering jit specialization of every entry
    point on each growth step.
    """
    free = g.capacity_slabs - int(g.next_free) + int(g.free_top)
    if free >= extra_slabs:
        return g
    target = max(int(g.next_free) - int(g.free_top) + extra_slabs,
                 g.capacity_slabs + g.capacity_slabs // 2)
    grow = next_pow2(target) - g.capacity_slabs

    def pad_rows(a, fill, dtype):
        pad = jnp.full((grow,) + a.shape[1:], fill, dtype=dtype)
        return jnp.concatenate([a, pad], axis=0)

    return dataclasses.replace(
        g,
        keys=pad_rows(g.keys, EMPTY_KEY, jnp.uint32),
        weights=(pad_rows(g.weights, 0.0, jnp.float32) if g.weighted else None),
        next_slab=pad_rows(g.next_slab, INVALID_SLAB, jnp.int32),
        slab_vertex=pad_rows(g.slab_vertex, -1, jnp.int32),
        free_list=pad_rows(g.free_list, INVALID_SLAB, jnp.int32),
        slab_new=pad_rows(g.slab_new, False, bool),
    )


def update_slab_pointers(g: SlabGraph) -> SlabGraph:
    """Paper's ``Graph.UpdateSlabPointers()`` (Fig. 2).

    Closes the current update epoch: clears every bucket's ``is_updated`` flag
    and repositions (upd_slab, upd_lane) to where the *next* insertion will
    land — the current tail slab / fill (lane = 128 == INVALID_LANE case falls
    out naturally: the next insert opens a fresh slab).  ``epoch_next_free``
    records the allocator watermark so "slab is wholly new" is a single compare.
    """
    return dataclasses.replace(
        g,
        upd_flag=jnp.zeros_like(g.upd_flag),
        upd_slab=g.tail_slab,
        upd_lane=g.tail_fill,
        epoch_next_free=g.next_free,
        slab_new=jnp.zeros_like(g.slab_new),
    )


# ============================================================================
# Host-side bulk construction (numpy fast path for experiments)
# ============================================================================

def from_edges_host(n_vertices: int, src: np.ndarray, dst: np.ndarray,
                    weights: Optional[np.ndarray] = None, *,
                    load_factor: float = 0.7, hashing: bool = True,
                    slack_slabs: int = 0) -> SlabGraph:
    """Build a SlabGraph from a static edge list on the host.

    Semantically identical to inserting the edges through ``insert_edges`` on
    an empty graph (the benchmarks do exactly that to measure build
    throughput); this numpy path exists so large test graphs construct fast.
    Duplicate (src,dst) pairs are dropped, matching insert semantics.
    """
    src = np.asarray(src, dtype=np.uint32)
    dst = np.asarray(dst, dtype=np.uint32)
    w = None if weights is None else np.asarray(weights, dtype=np.float32)

    # dedup
    key = src.astype(np.uint64) * np.uint64(2 ** 32) + dst.astype(np.uint64)
    _, uniq_idx = np.unique(key, return_index=True)
    uniq_idx.sort()
    src, dst = src[uniq_idx], dst[uniq_idx]
    if w is not None:
        w = w[uniq_idx]

    deg = np.bincount(src.astype(np.int64), minlength=n_vertices).astype(np.int32)
    bucket_count = plan_buckets(n_vertices, deg, load_factor=load_factor,
                                hashing=hashing)
    bucket_offset = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(bucket_count, out=bucket_offset[1:])
    n_buckets = int(bucket_offset[-1])

    # global bucket per edge (same multiplicative hash as device code)
    h = ((dst.astype(np.uint64) * 2654435761) & 0xFFFFFFFF).astype(np.uint64) >> 8
    b = bucket_offset[src.astype(np.int64)] + (h % bucket_count[src.astype(np.int64)])
    order = np.argsort(b, kind="stable")
    b_s, dst_s = b[order], dst[order]
    w_s = None if w is None else w[order]

    # per-bucket fill counts and slab layout
    per_bucket = np.bincount(b_s.astype(np.int64), minlength=n_buckets)
    extra = np.maximum(0, -(-per_bucket // SLAB_WIDTH) - 1)
    extra_off = np.zeros(n_buckets + 1, dtype=np.int64)
    np.cumsum(extra, out=extra_off[1:])
    total_slabs = n_buckets + int(extra_off[-1])
    # pow2-quantized like ensure_capacity: a cold-built store and a grown
    # store land on the SAME jit-shape ladder for the same size class.
    capacity = next_pow2(total_slabs + max(slack_slabs, total_slabs // 2 + 64))

    keys = np.full((capacity, SLAB_WIDTH), np.uint32(EMPTY_KEY), dtype=np.uint32)
    wpool = (np.zeros((capacity, SLAB_WIDTH), dtype=np.float32)
             if w is not None else None)
    nxt = np.full(capacity, -1, dtype=np.int32)
    slab_vertex = np.full(capacity, -1, dtype=np.int32)
    bucket_vertex = np.repeat(np.arange(n_vertices, dtype=np.int32), bucket_count)
    slab_vertex[:n_buckets] = bucket_vertex

    # rank of each edge within its bucket
    start = np.zeros(len(b_s), dtype=np.int64)
    if len(b_s):
        run_start = np.ones(len(b_s), dtype=bool)
        run_start[1:] = b_s[1:] != b_s[:-1]
        idx = np.arange(len(b_s), dtype=np.int64)
        start = np.maximum.accumulate(np.where(run_start, idx, 0))
    rank = np.arange(len(b_s), dtype=np.int64) - start

    slab_of = np.where(rank < SLAB_WIDTH,
                       b_s.astype(np.int64),
                       n_buckets + extra_off[b_s.astype(np.int64)]
                       + (rank // SLAB_WIDTH) - 1)
    lane_of = rank % SLAB_WIDTH
    keys[slab_of, lane_of] = dst_s
    if wpool is not None:
        wpool[slab_of, lane_of] = w_s

    # chain links + ownership for overflow slabs — fully vectorised (the
    # interpreted per-bucket loop here was O(#buckets) on every bulk build):
    # overflow slab k (global row n_buckets+k) belongs to the bucket whose
    # [extra_off[b], extra_off[b+1]) range contains k, links to row k+1
    # unless it is its bucket's last overflow slab, and the bucket's head
    # chain enters at its first overflow slab.
    total_extra = int(extra_off[-1])
    if total_extra:
        has = extra > 0
        nxt[np.nonzero(has)[0]] = (n_buckets + extra_off[:-1][has]).astype(
            np.int32)
        own = np.repeat(np.arange(n_buckets, dtype=np.int64), extra)
        ids = n_buckets + np.arange(total_extra, dtype=np.int64)
        slab_vertex[ids] = bucket_vertex[own]
        is_last = (ids - n_buckets + 1) == extra_off[own + 1]
        nxt[ids[~is_last]] = (ids[~is_last] + 1).astype(np.int32)

    tail_slab = np.where(extra > 0, n_buckets + extra_off[:-1] + extra - 1,
                         np.arange(n_buckets)).astype(np.int32)
    tail_fill = np.where(per_bucket > 0,
                         per_bucket - (-(-per_bucket // SLAB_WIDTH) - 1) * SLAB_WIDTH,
                         0).astype(np.int32)

    return SlabGraph(
        keys=jnp.asarray(keys),
        weights=None if wpool is None else jnp.asarray(wpool),
        next_slab=jnp.asarray(nxt),
        slab_vertex=jnp.asarray(slab_vertex),
        bucket_offset=jnp.asarray(bucket_offset.astype(np.int32)),
        bucket_count=jnp.asarray(bucket_count),
        bucket_vertex=jnp.asarray(bucket_vertex),
        tail_slab=jnp.asarray(tail_slab),
        tail_fill=jnp.asarray(tail_fill),
        upd_flag=jnp.zeros(n_buckets, dtype=bool),
        upd_slab=jnp.asarray(tail_slab),
        upd_lane=jnp.asarray(tail_fill),
        next_free=jnp.asarray(total_slabs, dtype=jnp.int32),
        epoch_next_free=jnp.asarray(total_slabs, dtype=jnp.int32),
        free_list=jnp.full((capacity,), -1, dtype=jnp.int32),
        free_top=jnp.asarray(0, dtype=jnp.int32),
        slab_new=jnp.zeros((capacity,), dtype=bool),
        degree=jnp.asarray(np.bincount(src.astype(np.int64),
                                       minlength=n_vertices).astype(np.int32)),
        n_edges=jnp.asarray(len(src), dtype=jnp.int32),
        n_vertices=n_vertices,
        n_buckets=n_buckets,
        weighted=w is not None,
    )


# ============================================================================
# Pool health (host side) — the maintenance plane's trigger inputs
# ============================================================================

def pool_stats(g: SlabGraph) -> dict:
    """Host-side pool-health snapshot driving ``MaintenancePolicy`` triggers.

    Lane accounting distinguishes *live* lanes (real neighbor ids) from
    *tombstone* lanes (deleted, still occupying a lane until compaction);
    ``dead_slabs`` counts allocated non-head slabs with zero live lanes —
    exactly what ``reclaim_free_slabs`` can hand back to the free list.
    Chain lengths are slabs per bucket (head included), the multiplier every
    chain-walk probe pays.
    """
    keys = np.asarray(g.keys)
    sv = np.asarray(g.slab_vertex)
    nxt = np.asarray(g.next_slab)
    S = g.capacity_slabs
    alloc = sv >= 0
    live_lane = alloc[:, None] & (keys < np.uint32(TOMBSTONE_KEY))
    tomb_lane = alloc[:, None] & (keys == np.uint32(TOMBSTONE_KEY))
    live_per_slab = live_lane.sum(axis=1)
    live_lanes = int(live_per_slab.sum())
    tombstone_lanes = int(tomb_lane.sum())
    allocated_slabs = int(alloc.sum())
    is_head = np.arange(S) < g.n_buckets
    dead_slabs = int((alloc & ~is_head & (live_per_slab == 0)).sum())

    # chain lengths: vectorised walk from every bucket head (head row = b)
    lengths = np.zeros(g.n_buckets, dtype=np.int64)
    cur = np.arange(g.n_buckets, dtype=np.int64)
    active = np.ones(g.n_buckets, dtype=bool)
    while active.any():
        lengths[active] += 1
        nxt_v = nxt[cur[active]]
        cur[active] = np.maximum(nxt_v, 0)
        active[active] = nxt_v >= 0

    occupied = live_lanes + tombstone_lanes
    return {
        "capacity_slabs": S,
        "next_free": int(g.next_free),
        "free_top": int(g.free_top),
        "free_slabs": S - int(g.next_free) + int(g.free_top),
        "allocated_slabs": allocated_slabs,
        "dead_slabs": dead_slabs,
        "live_lanes": live_lanes,
        "tombstone_lanes": tombstone_lanes,
        "tombstone_ratio": tombstone_lanes / max(1, occupied),
        "occupancy": live_lanes / max(1, allocated_slabs * SLAB_WIDTH),
        "max_chain": int(lengths.max()) if len(lengths) else 0,
        "mean_chain": float(lengths.mean()) if len(lengths) else 0.0,
        "pool_bytes": int(g.keys.size * 4 +
                          (g.weights.size * 4 if g.weighted else 0)),
        "n_edges": int(g.n_edges),
    }
