"""Hashing and sentinel constants for the slab pool.

Meerkat (paper §2) stores a vertex's adjacency in a per-vertex hash table whose
buckets are slab lists.  Sentinels follow the paper: an ``EMPTY_KEY`` marks a
never-used lane, a ``TOMBSTONE_KEY`` marks a deleted lane.  On TPU we keep the
same uint32 encoding (UINT32_MAX-1 / UINT32_MAX-2); ``INVALID_VERTEX`` pads
batches.

The bucket hash is the multiplicative (Knuth/Fibonacci) hash — cheap, vectorises
to a single uint32 multiply on the VPU, and distributes power-law neighbor ids
well enough for the load-balance role it plays in IterationScheme2.
"""
from __future__ import annotations

import jax.numpy as jnp

# --- lane geometry -----------------------------------------------------------
# GPU Meerkat: slab = 32 lanes x 4B = 128B (one L1 line, one warp).
# TPU: slab = 128 lanes x 4B = 512B  (one full vector-register row; the natural
# unit of coalesced VMEM access).  See DESIGN.md §2.
SLAB_WIDTH = 128

# --- sentinels ---------------------------------------------------------------
EMPTY_KEY = jnp.uint32(0xFFFFFFFE)      # lane never populated
TOMBSTONE_KEY = jnp.uint32(0xFFFFFFFD)  # lane held a vertex, now deleted
INVALID_VERTEX = jnp.uint32(0xFFFFFFFF) # batch padding / invalid id
INVALID_SLAB = jnp.int32(-1)            # end-of-chain "pointer"
INVALID_LANE = jnp.int32(-1)

_KNUTH = jnp.uint32(2654435761)


def bucket_hash(dst: jnp.ndarray, n_buckets: jnp.ndarray) -> jnp.ndarray:
    """Hash a destination-vertex id into one of ``n_buckets`` slab lists.

    ``dst`` uint32, ``n_buckets`` int32 (>=1).  Matches the paper's scheme of
    hashing the *destination* vertex to pick the slab list within the source
    vertex's table.  With hashing disabled (n_buckets == 1) this is 0, i.e. the
    "single bucket" mode the paper uses for BFS/SSSP/PageRank.
    """
    h = (dst.astype(jnp.uint32) * _KNUTH) >> jnp.uint32(8)
    return (h % n_buckets.astype(jnp.uint32)).astype(jnp.int32)


def is_valid_vertex(v: jnp.ndarray) -> jnp.ndarray:
    """Paper's ``is_valid_vertex()``: lane holds a real neighbor id."""
    v = v.astype(jnp.uint32)
    return (v != EMPTY_KEY) & (v != TOMBSTONE_KEY) & (v != INVALID_VERTEX)
