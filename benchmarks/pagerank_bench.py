"""Paper Figs. 8–10 — PageRank: static (hashing on/off, vs CSR baseline),
dynamic warm-start speedups + iteration counts across batch sizes."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.algorithms import pagerank, pagerank_dynamic
from repro.core import ensure_capacity, from_edges_host, insert_edges
from repro.data.synth import rmat_edges

from .timing import row, time_fn


def pad(a, n):
    out = np.full(n, 0xFFFFFFFF, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (100000, 1000000)
    src, dst = rmat_edges(V, E, seed=6)
    E = len(src)
    uniq = set(zip(src.tolist(), dst.tolist()))
    out_deg = np.zeros(V, np.int32)
    for s, _ in uniq:
        out_deg[s] += 1
    out_deg_j = jnp.asarray(out_deg)

    # static: hashing off vs on (paper §6.2: off is 1.36–1.62× for high-deg)
    g_off = from_edges_host(V, dst, src, hashing=False)
    g_on = from_edges_host(V, dst, src, hashing=True)
    us_off = time_fn(lambda: pagerank(g_off, out_deg_j), iters=3)
    us_on = time_fn(lambda: pagerank(g_on, out_deg_j), iters=3)
    row("pagerank_static_nohash", us_off, f"V={V};E={E}")
    row("pagerank_static_hash", us_on,
        f"nohash_speedup={us_on / us_off:.2f}x")

    # pallas kernel path
    us_pal = time_fn(lambda: pagerank(g_off, out_deg_j,
                                      contrib_impl="pallas"), iters=3)
    row("pagerank_static_pallas", us_pal,
        f"vs_ref={us_off / us_pal:.2f}x")

    # CSR matvec baseline (Hornet-style contiguous traversal == segment sum
    # over CSR) — same superstep count for fairness
    order = np.argsort(dst, kind="stable")
    seg = jnp.asarray(dst[order].astype(np.int32))
    srcs = jnp.asarray(src[order].astype(np.int32))

    import jax

    @jax.jit
    def csr_pagerank(out_deg):
        pr = jnp.full((V,), 1.0 / V, jnp.float32)

        def body(carry):
            pr, delta, it = carry
            contrib = jnp.where(out_deg > 0,
                                pr / jnp.maximum(out_deg, 1), 0.0)
            sums = jax.ops.segment_sum(contrib[srcs], seg, num_segments=V)
            tele = jnp.sum(jnp.where(out_deg == 0, pr, 0.0)) / V
            new = 0.15 / V + 0.85 * (sums + tele)
            return new, jnp.sum(jnp.abs(new - pr)), it + 1

        def cond(carry):
            return (carry[1] > 1e-5) & (carry[2] < 100)

        pr, _, it = jax.lax.while_loop(
            cond, body, (pr, jnp.asarray(jnp.inf), jnp.asarray(0)))
        return pr, it

    us_csr = time_fn(lambda: csr_pagerank(out_deg_j), iters=3)
    row("pagerank_static_csr_baseline", us_csr,
        f"meerkat_vs_csr={us_csr / us_off:.2f}x")

    # dynamic warm start: batches 1K..8K (paper 1K..10K)
    pr0, it0 = pagerank(g_off, out_deg_j)
    rng = np.random.default_rng(7)
    for bs in (1024, 4096, 8192):
        bs_s = rng.integers(0, V, bs).astype(np.uint32)
        bs_d = rng.integers(0, V, bs).astype(np.uint32)
        g2 = ensure_capacity(g_off, bs + 64)
        g2, ins = insert_edges(g2, pad(bs_d, bs), pad(bs_s, bs))  # in-edges
        od = out_deg.copy()
        ins_np = np.asarray(ins)
        for s in bs_s[ins_np[:len(bs_s)]]:
            od[s] += 1
        odj = jnp.asarray(od)
        us_warm = time_fn(lambda: pagerank_dynamic(g2, odj, pr0), iters=3)
        us_cold = time_fn(lambda: pagerank(g2, odj), iters=3)
        _, it_warm = pagerank_dynamic(g2, odj, pr0)
        _, it_cold = pagerank(g2, odj)
        row(f"pagerank_dyn_batch{bs}", us_warm,
            f"speedup={us_cold / us_warm:.2f}x;iters={int(it_warm)}"
            f"/{int(it_cold)}")
