"""Sustained mixed update+query serving throughput: the PR-1-era hand-wired
loop (double insertion into ``g``/``g_in``, host-side ``np.add.at`` out-degree
shadow, epochs never closed, no deletions) vs the `repro.stream` subsystem
(`GraphStore` + `PropertyRegistry` + `RequestPipeline`).

Both paths serve the SAME insert+query request sequence (the legacy loop
cannot delete), measured after a warmup pass compiles every kernel; the
subsystem additionally serves a mixed stream with deletions — the workload
the paper actually benchmarks and the legacy loop cannot express.  Results
append to the CSV stream and land in ``BENCH_serve.json``.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax.numpy as jnp

from repro.algorithms import (bfs_incremental, bfs_stream_property,
                              bfs_tree_static, pagerank, pagerank_dynamic,
                              pagerank_stream_property,
                              wcc_incremental_batch, wcc_static,
                              wcc_stream_property)
from repro.core import (ensure_capacity, from_edges_host, insert_edges,
                        query_edges)
from repro.data.synth import rmat_edges
from repro.obs import flight
from repro.obs.metrics import Histogram
from repro.stream import (GraphStore, MembershipQuery, PropertyRead,
                          PropertyRegistry, RequestPipeline, UpdateBatch)

from .timing import row

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"

KINDS = ("update", "pagerank", "bfs", "wcc", "member")


def make_workload(V, rng, *, n_requests, batch, delete_frac, present):
    """(kind, payload) list; deletes sampled from a running present-ledger."""
    present = set(present)
    out = []
    for i in range(n_requests):
        kind = KINDS[i % len(KINDS)]
        if kind == "update":
            n_del = int(batch * delete_frac)
            ins = rng.integers(0, V, (batch - n_del, 2)).astype(np.uint32)
            ins = ins[ins[:, 0] != ins[:, 1]]
            pool = np.array(sorted(present), np.uint32)
            dels = pool[rng.choice(len(pool), min(n_del, len(pool)),
                                   replace=False)] if n_del else \
                np.zeros((0, 2), np.uint32)
            present -= {(int(s), int(d)) for s, d in dels}
            present |= {(int(s), int(d)) for s, d in ins}
            out.append((kind, (ins, dels)))
        elif kind == "member":
            out.append((kind, rng.integers(0, V, (1024, 2)).astype(np.uint32)))
        else:
            out.append((kind, None))
    return out


def legacy_loop(V, src, dst, workload, *, slack, edge_cap, batch_pad):
    """The old `launch/serve.py` datapath, verbatim warts included."""
    g = from_edges_host(V, src, dst, hashing=False, slack_slabs=slack)
    g_in = from_edges_host(V, dst, src, hashing=False, slack_slabs=slack)
    out_deg = np.bincount(src, minlength=V).astype(np.int32)  # host shadow
    pr, _ = pagerank(g_in, jnp.asarray(out_deg))
    bfs_state, _ = bfs_tree_static(g, 0, edge_capacity=edge_cap)
    labels = wcc_static(g)

    def pad(a, n):
        out = np.full(n, 0xFFFFFFFF, np.uint32)
        out[:len(a)] = a
        return jnp.asarray(out)

    t0 = time.perf_counter()
    for kind, payload in workload:
        if kind == "update":
            ins, _ = payload  # the legacy loop never issues deletes
            bs, bd = ins[:, 0], ins[:, 1]
            g = ensure_capacity(g, batch_pad + 64)
            g_in = ensure_capacity(g_in, batch_pad + 64)
            g, insd = insert_edges(g, pad(bs, batch_pad), pad(bd, batch_pad))
            g_in, _ = insert_edges(g_in, pad(bd, batch_pad),
                                   pad(bs, batch_pad))
            ins_np = np.asarray(insd)[:len(bs)]
            np.add.at(out_deg, bs[ins_np].astype(np.int64), 1)
            bfs_state, _ = bfs_incremental(
                g, bfs_state, pad(bs, batch_pad), pad(bd, batch_pad),
                jnp.asarray(insd), edge_capacity=edge_cap)
            labels = wcc_incremental_batch(labels, pad(bs, batch_pad),
                                           pad(bd, batch_pad),
                                           jnp.asarray(insd))
        elif kind == "pagerank":
            pr, _ = pagerank_dynamic(g_in, jnp.asarray(out_deg), pr)
            float(pr.max())
        elif kind == "bfs":
            int((np.asarray(bfs_state.dist) < 1e29).sum())
        elif kind == "wcc":
            int((np.asarray(labels) == np.arange(V)).sum())
        else:
            found = query_edges(g, jnp.asarray(payload[:, 0]),
                                jnp.asarray(payload[:, 1]))
            int(np.asarray(found).sum())
    return time.perf_counter() - t0


def stream_requests(workload, *, with_deletes):
    reqs = []
    for kind, payload in workload:
        if kind == "update":
            ins, dels = payload
            reqs.append(UpdateBatch(
                ins_src=ins[:, 0], ins_dst=ins[:, 1],
                del_src=dels[:, 0] if with_deletes and len(dels) else (),
                del_dst=dels[:, 1] if with_deletes and len(dels) else ()))
        elif kind == "member":
            reqs.append(MembershipQuery(src=payload[:, 0],
                                        dst=payload[:, 1]))
        else:
            reqs.append(PropertyRead({"pagerank": "pagerank", "bfs": "bfs_0",
                                      "wcc": "wcc"}[kind]))
    return reqs


def _build_pipeline(V, src, dst, *, slack, edge_cap, policy="lazy"):
    # no registered analytic reads the symmetric view — don't maintain it
    store = GraphStore.from_edges(V, src, dst, hashing=False,
                                  slack_slabs=slack, with_symmetric=False)
    registry = PropertyRegistry(store)
    registry.register(pagerank_stream_property(), policy=policy)
    registry.register(bfs_stream_property(0, edge_capacity=edge_cap),
                      policy=policy)
    registry.register(wcc_stream_property(), policy=policy)
    return RequestPipeline(store, registry, coalesce=False)


def stream_loop(V, src, dst, requests, *, slack, edge_cap, policy="lazy"):
    pipeline = _build_pipeline(V, src, dst, slack=slack, edge_cap=edge_cap,
                               policy=policy)
    t0 = time.perf_counter()
    pipeline.run(requests)
    return time.perf_counter() - t0


def open_loop(V, src, dst, requests, *, slack, edge_cap, rate,
              policy="lazy"):
    """Open-loop serving: requests ARRIVE on a fixed schedule (``rate``
    req/s) regardless of service progress, and each request's latency is
    completion − scheduled arrival — queueing delay included.  This is
    the SLO-relevant measurement the closed-loop rows above cannot give
    (closed loops let a slow server throttle its own offered load).
    Returns per-request-class exact-percentile latency histograms and the
    achieved throughput."""
    pipeline = _build_pipeline(V, src, dst, slack=slack, edge_cap=edge_cap,
                               policy=policy)
    lat = {}
    t0 = time.perf_counter()
    for i, req in enumerate(requests):
        arrival = t0 + i / rate
        now = time.perf_counter()
        if now < arrival:
            time.sleep(arrival - now)
        resp = pipeline.run([req])[0]
        done = time.perf_counter()
        lat.setdefault(resp.kind, Histogram()).record(done - arrival)
    achieved = len(requests) / (time.perf_counter() - t0)
    return lat, achieved


def run(scale: str = "quick"):
    V, E, n_req, batch = ((5000, 30000, 20, 512) if scale == "quick"
                          else (50000, 400000, 50, 2048))
    rng = np.random.default_rng(3)
    src, dst = rmat_edges(V, E, seed=3)
    present = set(zip(src.tolist(), dst.tolist()))
    slack = n_req * batch // 64 + 512
    edge_cap = len(src) + n_req * batch + 4096

    workload = make_workload(V, np.random.default_rng(4), n_requests=n_req,
                             batch=batch, delete_frac=0.25, present=present)
    ins_only = stream_requests(workload, with_deletes=False)
    mixed = stream_requests(workload, with_deletes=True)

    # warmup pass compiles every kernel on both paths, then measure
    legacy_loop(V, src, dst, workload, slack=slack, edge_cap=edge_cap,
                batch_pad=batch)
    t_legacy = legacy_loop(V, src, dst, workload, slack=slack,
                           edge_cap=edge_cap, batch_pad=batch)
    stream_loop(V, src, dst, ins_only, slack=slack, edge_cap=edge_cap)
    t_stream = stream_loop(V, src, dst, ins_only, slack=slack,
                           edge_cap=edge_cap)
    stream_loop(V, src, dst, mixed, slack=slack, edge_cap=edge_cap)
    t_mixed = stream_loop(V, src, dst, mixed, slack=slack, edge_cap=edge_cap)

    rps = {
        "legacy_insert_only": round(n_req / t_legacy, 2),
        "stream_insert_only": round(n_req / t_stream, 2),
        "stream_mixed_del25": round(n_req / t_mixed, 2),
    }

    # -- flight-recorder overhead guard (ISSUE 10): the black box is ON by
    # default, so its cost must be measured, not assumed.  A/B the
    # closed-loop mixed serve with the ring armed vs stripped in
    # interleaved pairs (drift cancels), min-of-N each arm; extend with
    # two more pairs before failing so one scheduler hiccup can't trip it.
    on_s, off_s = [], []

    def _overhead_pair():
        flight.enable()
        on_s.append(stream_loop(V, src, dst, mixed, slack=slack,
                                edge_cap=edge_cap))
        flight.disable()
        try:
            off_s.append(stream_loop(V, src, dst, mixed, slack=slack,
                                     edge_cap=edge_cap))
        finally:
            flight.enable()          # the black box stays on

    for _ in range(3):
        _overhead_pair()
    overhead_x = min(on_s) / min(off_s)
    if overhead_x > 1.05:
        for _ in range(2):
            _overhead_pair()
        overhead_x = min(on_s) / min(off_s)
    row("serve_flight_overhead", min(on_s) * 1e6 / n_req,
        f"overhead_x={overhead_x:.3f};pairs={len(on_s)}")
    assert overhead_x < 1.05, (
        f"flight recorder overhead {overhead_x:.3f}x exceeds the 5% "
        f"always-on budget (on={min(on_s):.3f}s off={min(off_s):.3f}s)")
    row("serve_legacy", t_legacy * 1e6 / n_req,
        f"req_per_s={rps['legacy_insert_only']}")
    row("serve_stream", t_stream * 1e6 / n_req,
        f"req_per_s={rps['stream_insert_only']};"
        f"speedup={t_legacy / t_stream:.2f}x")
    row("serve_stream_mixed", t_mixed * 1e6 / n_req,
        f"req_per_s={rps['stream_mixed_del25']};delete_frac=0.25")

    # open-loop latency: a DEDICATED longer request stream (the closed-loop
    # mix serves too few requests per class for a p95/p99 to mean
    # anything), offered at 70% of the measured closed-loop throughput
    # (stable queue, nonzero wait) — every kernel is already compiled by
    # the closed-loop passes above.  Sample counts are recorded next to
    # every percentile; the regress gate skips tails with too few.
    n_open = 150 if scale == "quick" else 250
    open_workload = make_workload(
        V, np.random.default_rng(7), n_requests=n_open, batch=batch,
        delete_frac=0.25, present=present)
    open_reqs = stream_requests(open_workload, with_deletes=True)
    open_edge_cap = len(src) + (n_open // len(KINDS) + 1) * batch + 4096
    offered = max(0.5, 0.7 * rps["stream_mixed_del25"])
    lat, achieved = open_loop(V, src, dst, open_reqs, slack=slack,
                              edge_cap=open_edge_cap, rate=offered)
    latency_ms = {}
    for cls, h in sorted(lat.items()):
        s = h.summary()
        latency_ms[cls] = {
            "count": s["count"],
            "samples": s["count"],
            "mean": round(1e3 * s["mean_s"], 2),
            "p50": round(1e3 * s["p50_s"], 2),
            "p95": round(1e3 * s["p95_s"], 2),
            "p99": round(1e3 * s["p99_s"], 2),
        }
        row(f"serve_openloop_{cls}", s["p50_s"] * 1e6,
            f"n={s['count']};"
            f"p50_ms={latency_ms[cls]['p50']};p95_ms={latency_ms[cls]['p95']};"
            f"p99_ms={latency_ms[cls]['p99']}")

    import jax
    payload = {
        "backend": jax.default_backend(),
        "scale": scale,
        "graph": {"V": V, "E": int(E)},
        "workload": {"requests": n_req, "batch": batch,
                     "mix": "update/pagerank/bfs/wcc/member round-robin"},
        "note": ("legacy = PR-1 hand-wired serve loop (double insertion, "
                 "host out-degree shadow, no epoch close, no deletes); "
                 "stream = GraphStore+PropertyRegistry+RequestPipeline. "
                 "Same insert+query sequence for the A/B; the mixed row "
                 "adds 25% deletions, which only the subsystem serves."),
        "requests_per_sec": rps,
        "speedup_insert_only": round(t_legacy / t_stream, 3),
        "flight_overhead_x": round(overhead_x, 3),
        "open_loop": {
            "requests": n_open,
            "offered_req_per_s": round(offered, 2),
            "achieved_req_per_s": round(achieved, 2),
            "note": ("fixed-schedule arrivals at 70% of closed-loop mixed "
                     "throughput over a dedicated longer stream; latency = "
                     "completion - scheduled arrival (queue wait "
                     "included), exact percentiles with per-class sample "
                     "counts"),
        },
        "latency_ms": latency_ms,
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("serve_bench_json", 0.0, str(_OUT.name))
