"""Paper Table 5 — memory: pooled head-slab allocation vs per-vertex
allocation (SlabHash default), plus the Hornet-like footprint, across graphs
of varying degree skew."""
from __future__ import annotations

import numpy as np

from repro.core import SLAB_WIDTH, from_edges_host, occupancy_stats
from repro.data.synth import rmat_edges, uniform_edges

from . import hornet_like as HL
from .timing import row


#: GPU allocator model for the per-vertex-cudaMalloc strategy the paper
#: replaces: every allocation is page-rounded + carries allocator metadata.
PAGE = 4096
META = 64


def per_vertex_alloc_bytes(n_buckets_per_vertex: np.ndarray,
                           extra_slabs: int) -> int:
    """One cudaMalloc per vertex's head slabs (paper §2 'Memory Allocation')."""
    slab_bytes = SLAB_WIDTH * 4
    per_alloc = np.ceil(n_buckets_per_vertex * slab_bytes / PAGE) * PAGE + META
    return int(per_alloc.sum() + extra_slabs * slab_bytes)


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (100000, 1500000)
    for name, (src, dst) in {
        "rmat": rmat_edges(V, E, seed=12) and rmat_edges(V, E, seed=12),
        "uniform": uniform_edges(V, E, seed=12),
    }.items():
        g = from_edges_host(V, src, dst, hashing=True)
        stats = occupancy_stats(g)
        pooled = stats["repr_bytes"]
        bc = np.asarray(g.bucket_count)
        extra = stats["allocated_slabs"] - int(bc.sum())
        per_vertex = per_vertex_alloc_bytes(bc, extra)
        h = HL.from_edges_host(V, src, dst)
        row(f"memory_{name}_pooled_MiB", pooled / 2 ** 20,
            f"savings_vs_pervertex={per_vertex / pooled:.2f}x")
        row(f"memory_{name}_pervertex_MiB", per_vertex / 2 ** 20,
            f"occupancy={stats['occupancy']:.2f}")
        row(f"memory_{name}_hornet_like_MiB", HL.nbytes(h) / 2 ** 20, "")
