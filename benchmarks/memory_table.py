"""Paper Table 5 — memory: pooled head-slab allocation vs per-vertex
allocation (SlabHash default), plus the Hornet-like footprint, across graphs
of varying degree skew; plus pool-health rows (``core.pool_stats``) showing
what churn does to the pool and what the slab-compaction plane wins back."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (SLAB_WIDTH, ensure_capacity, from_edges_host,
                        occupancy_stats, pool_stats, update_slab_pointers)
from repro.core.batch import apply_update
from repro.data.synth import rmat_edges, uniform_edges
from repro.kernels.slab_compact import compact

from . import hornet_like as HL
from .timing import row


#: GPU allocator model for the per-vertex-cudaMalloc strategy the paper
#: replaces: every allocation is page-rounded + carries allocator metadata.
PAGE = 4096
META = 64


def per_vertex_alloc_bytes(n_buckets_per_vertex: np.ndarray,
                           extra_slabs: int) -> int:
    """One cudaMalloc per vertex's head slabs (paper §2 'Memory Allocation')."""
    slab_bytes = SLAB_WIDTH * 4
    per_alloc = np.ceil(n_buckets_per_vertex * slab_bytes / PAGE) * PAGE + META
    return int(per_alloc.sum() + extra_slabs * slab_bytes)


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (100000, 1500000)
    for name, (src, dst) in {
        "rmat": rmat_edges(V, E, seed=12) and rmat_edges(V, E, seed=12),
        "uniform": uniform_edges(V, E, seed=12),
    }.items():
        g = from_edges_host(V, src, dst, hashing=True)
        stats = occupancy_stats(g)
        pooled = stats["repr_bytes"]
        bc = np.asarray(g.bucket_count)
        extra = stats["allocated_slabs"] - int(bc.sum())
        per_vertex = per_vertex_alloc_bytes(bc, extra)
        h = HL.from_edges_host(V, src, dst)
        row(f"memory_{name}_pooled_MiB", pooled / 2 ** 20,
            f"savings_vs_pervertex={per_vertex / pooled:.2f}x")
        row(f"memory_{name}_pervertex_MiB", per_vertex / 2 ** 20,
            f"occupancy={stats['occupancy']:.2f}")
        row(f"memory_{name}_hornet_like_MiB", HL.nbytes(h) / 2 ** 20, "")

    # --- pool health under churn: tombstones in, compaction out -------------
    # hub-skewed stream (the regime where chains really grow — power-law
    # sources): deletes tombstone hub chains, inserts keep extending them.
    # V is small here so the head-slab prefix doesn't floor the capacity.
    rng = np.random.default_rng(12)
    V, hubs = (2048, 64) if scale == "quick" else (8192, 256)
    E_hub = 32 * V
    src = rng.integers(0, hubs, E_hub).astype(np.uint32)
    dst = rng.integers(0, V, E_hub).astype(np.uint32)
    g = from_edges_host(V, src, dst, hashing=False)
    epochs, B = (8, 2048) if scale == "quick" else (12, 8192)
    for _ in range(epochs):
        di = rng.choice(len(src), B, replace=False)
        ins_s = rng.integers(0, hubs, B).astype(np.uint32)
        ins_d = rng.integers(0, V, B).astype(np.uint32)
        g = ensure_capacity(g, B + 64)
        g, _, _ = apply_update(g, jnp.asarray(ins_s), jnp.asarray(ins_d),
                               None,
                               jnp.asarray(src[di]), jnp.asarray(dst[di]))
        g = update_slab_pointers(g)
    churned = pool_stats(g)
    g2, rep = compact(g)
    compacted = pool_stats(g2)
    row("memory_churned_pool_MiB",
        churned["capacity_slabs"] * SLAB_WIDTH * 4 / 2 ** 20,
        f"tombstone_ratio={churned['tombstone_ratio']:.3f};"
        f"occupancy={churned['occupancy']:.3f};"
        f"mean_chain={churned['mean_chain']:.2f}")
    row("memory_compacted_pool_MiB",
        compacted["capacity_slabs"] * SLAB_WIDTH * 4 / 2 ** 20,
        f"tombstone_ratio={compacted['tombstone_ratio']:.3f};"
        f"occupancy={compacted['occupancy']:.3f};"
        f"mean_chain={compacted['mean_chain']:.2f};"
        f"capacity={rep.old_capacity}->{rep.new_capacity}")
