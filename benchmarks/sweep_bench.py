"""Old-path vs slab-sweep-engine super-steps for BFS / SSSP / WCC / PageRank.

Times the full iterate-to-convergence run of each algorithm through both
data paths (identical results, identical iteration counts — asserted), and
derives per-super-step microseconds.  Results append to the CSV stream and
are also written to ``BENCH_sweep.json`` at the repo root, seeding the perf
trajectory for future scaling PRs.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np
import jax.numpy as jnp

from repro.algorithms import (bfs_vanilla, pagerank, sssp_static,
                              wcc_labelprop_sweep, wcc_static)
from repro.core import from_edges_host, transpose_host
from repro.data.synth import rmat_edges

from .timing import row, time_fn

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (100000, 1000000)
    src, dst = rmat_edges(V, E, seed=11)
    E = len(src)
    w = np.random.default_rng(13).uniform(0.5, 4.0, E).astype(np.float32)
    cap = E + 4096

    g = from_edges_host(V, src, dst, hashing=False)
    gw = from_edges_host(V, src, dst, w, hashing=False)
    g_in = transpose_host(g)
    gw_in = transpose_host(gw)
    g_sym = transpose_host(g, symmetric=True)
    g_pr = from_edges_host(V, dst, src, hashing=False)   # in-edge storage
    out_deg = jnp.asarray(np.asarray(g.degree))

    results = []

    def record(name, old_us, new_us, iters, extra=""):
        per_old = old_us / max(iters, 1)
        per_new = new_us / max(iters, 1)
        results.append({
            "name": name, "iters": iters,
            "old_us": round(old_us, 1), "new_us": round(new_us, 1),
            "old_us_per_superstep": round(per_old, 2),
            "new_us_per_superstep": round(per_new, 2),
            "speedup": round(old_us / new_us, 3) if new_us else None,
        })
        row(f"sweep_{name}_old", old_us, f"iters={iters}{extra}")
        row(f"sweep_{name}_engine", new_us,
            f"speedup={old_us / new_us:.2f}x;us_per_step={per_new:.1f}")

    # --- BFS (vanilla levels) ---------------------------------------------
    d_old, it = bfs_vanilla(g, src=0, edge_capacity=cap)
    d_new, it2 = bfs_vanilla(g, src=0, edge_capacity=cap, g_in=g_in)
    assert np.array_equal(np.asarray(d_old), np.asarray(d_new))
    assert int(it) == int(it2)
    old = time_fn(lambda: bfs_vanilla(g, src=0, edge_capacity=cap))
    new = time_fn(lambda: bfs_vanilla(g, src=0, edge_capacity=cap,
                                      g_in=g_in))
    record("bfs", old, new, int(it))

    # --- SSSP (tree relaxation) -------------------------------------------
    s_old, it = sssp_static(gw, 0, edge_capacity=cap)
    s_new, it2 = sssp_static(gw, 0, edge_capacity=cap, g_in=gw_in)
    assert np.array_equal(np.asarray(s_old.dist), np.asarray(s_new.dist))
    assert int(it) == int(it2)
    old = time_fn(lambda: sssp_static(gw, 0, edge_capacity=cap))
    new = time_fn(lambda: sssp_static(gw, 0, edge_capacity=cap, g_in=gw_in))
    record("sssp", old, new, int(it))

    # --- WCC (union-find sweep vs min-label propagation) ------------------
    labels_uf = wcc_static(g_sym)
    labels_lp, it = wcc_labelprop_sweep(g_sym)
    n_uf = int(jnp.sum((labels_uf == jnp.arange(V)).astype(jnp.int32)))
    n_lp = int(jnp.sum((labels_lp == jnp.arange(V)).astype(jnp.int32)))
    assert n_uf == n_lp, (n_uf, n_lp)
    old = time_fn(lambda: wcc_static(g_sym))
    new = time_fn(lambda: wcc_labelprop_sweep(g_sym))
    record("wcc", old, new, int(it), extra=f";components={n_lp}")

    # --- PageRank (ref oracle vs engine sum semiring) ---------------------
    pr_old, it = pagerank(g_pr, out_deg, contrib_impl="ref")
    pr_new, it2 = pagerank(g_pr, out_deg, contrib_impl="sweep")
    assert np.array_equal(np.asarray(pr_old), np.asarray(pr_new))
    assert int(it) == int(it2)
    old = time_fn(lambda: pagerank(g_pr, out_deg, contrib_impl="ref"),
                  iters=3)
    new = time_fn(lambda: pagerank(g_pr, out_deg, contrib_impl="sweep"),
                  iters=3)
    record("pagerank", old, new, int(it))

    import jax
    payload = {
        "backend": jax.default_backend(),
        "scale": scale,
        "graph": {"V": V, "E": int(E)},
        "note": ("engine impl=auto: fused Pallas on TPU, fused-jnp ref "
                 "elsewhere; old path = expand_vertices/EdgeFrontier "
                 "(BFS/SSSP), union-find (WCC), in-module oracle "
                 "(PageRank)"),
        "results": results,
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("sweep_bench_json", 0.0, str(_OUT.name))
