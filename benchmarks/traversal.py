"""Paper Fig. 6 — static BFS / SSSP: VANILLA vs TREE variants on Meerkat,
vs a CSR (Hornet-like) level-synchronous baseline."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.algorithms import bfs_tree_static, bfs_vanilla, sssp_static
from repro.core import from_edges_host
from repro.data.synth import rmat_edges

from .timing import row, time_fn


def csr_bfs(indptr, indices, n, src=0):
    """Host-side CSR BFS reference (the Hornet-style static baseline)."""
    import collections
    dist = np.full(n, -1, np.int64)
    dist[src] = 0
    q = collections.deque([src])
    while q:
        u = q.popleft()
        for v in indices[indptr[u]:indptr[u + 1]]:
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (100000, 1000000)
    src, dst = rmat_edges(V, E, seed=2)
    E = len(src)
    w = np.random.default_rng(3).uniform(0.5, 4.0, E).astype(np.float32)

    g = from_edges_host(V, src, dst, hashing=False)   # paper: hashing off
    gw = from_edges_host(V, src, dst, w, hashing=False)
    g_hash = from_edges_host(V, src, dst, hashing=True)
    cap = E + 4096

    us_v = time_fn(lambda: bfs_vanilla(g, src=0, edge_capacity=cap))
    row("bfs_vanilla_meerkat", us_v, f"V={V};E={E}")
    us_t = time_fn(lambda: bfs_tree_static(g, 0, edge_capacity=cap))
    row("bfs_tree_meerkat", us_t,
        f"tree_overhead={(us_t / us_v - 1) * 100:.1f}%")  # paper: ~17%

    mb = int(np.max(np.asarray(g_hash.bucket_count)))
    us_vh = time_fn(lambda: bfs_vanilla(g_hash, src=0, edge_capacity=cap,
                                        max_bpv=mb))
    row("bfs_vanilla_meerkat_hashed", us_vh,
        f"hashing_off_speedup={us_vh / us_v:.2f}x")       # paper: ~1.11x

    us_s = time_fn(lambda: sssp_static(gw, 0, edge_capacity=cap))
    row("sssp_tree_meerkat", us_s, "")

    # paper §3.4: full-traversal IterationScheme1 (SlabIterator chain walk
    # per vertex) vs Scheme2 (flattened work-list) — our analogues are the
    # expand_vertices chain walk vs the dense pool sweep.
    import jax
    import jax.numpy as jnp
    from repro.core import expand_vertices, pool_edges

    @jax.jit
    def sweep(gg):
        view = pool_edges(gg)
        return jnp.sum(jnp.where(view.valid, view.dst, 0).astype(jnp.uint32))

    verts = jnp.arange(V, dtype=jnp.uint32)
    vmask = jnp.ones(V, bool)
    us_sweep = time_fn(lambda: sweep(g))
    us_expand = time_fn(lambda: expand_vertices(
        g, verts, vmask, out_capacity=cap, max_bpv=1))
    row("full_traversal_scheme2_pool_sweep", us_sweep, "")
    row("full_traversal_scheme1_chain_walk", us_expand,
        f"scheme2_speedup={us_expand / us_sweep:.2f}x")

    # CSR baseline (host BFS — the contiguous-block traversal model)
    order = np.argsort(src, kind="stable")
    indptr = np.zeros(V + 1, np.int64)
    np.add.at(indptr, src.astype(np.int64) + 1, 1)
    np.cumsum(indptr, out=indptr)
    indices = dst[order].astype(np.int64)
    import time as _t
    t0 = _t.perf_counter()
    ref = csr_bfs(indptr, indices, V)
    us_c = (_t.perf_counter() - t0) * 1e6
    row("bfs_csr_host_baseline", us_c, f"speedup={us_c / us_v:.2f}x")

    # correctness cross-check while we're here
    dist, _ = bfs_vanilla(g, src=0, edge_capacity=cap)
    dist = np.asarray(dist)
    reach = ref >= 0
    assert np.array_equal(dist[reach], ref[reach]), "BFS mismatch vs CSR ref"
