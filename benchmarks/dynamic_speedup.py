"""Paper Fig. 7 — s^n_b self-relative speedups: cumulative re-run-static vs
incremental/decremental BFS and SSSP over a sequence of edge batches."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.algorithms import (bfs_decremental, bfs_incremental,
                              bfs_tree_static, sssp_decremental,
                              sssp_incremental, sssp_static)
from repro.core import delete_edges, ensure_capacity, from_edges_host, \
    insert_edges
from repro.data.synth import rmat_edges

from .timing import row, time_fn


def pad(a, n, fill=0xFFFFFFFF):
    out = np.full(n, fill, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (100000, 1000000)
    n_batches, bs = (10, 1024) if scale == "quick" else (10, 10240)
    rng = np.random.default_rng(5)
    src, dst = rmat_edges(V, E, seed=4)
    E = len(src)
    w = rng.uniform(0.5, 4.0, E).astype(np.float32)
    cap = E + n_batches * bs + 4096

    for algo in ("bfs", "sssp"):
        weighted = algo == "sssp"
        static_fn = sssp_static if weighted else bfs_tree_static
        inc_fn = sssp_incremental if weighted else bfs_incremental

        # ---- incremental ---------------------------------------------------
        g = from_edges_host(V, src, dst, w if weighted else None,
                            hashing=False, slack_slabs=n_batches * bs + 64)
        state, _ = static_fn(g, 0, edge_capacity=cap)
        t_static = t_dyn = 0.0
        for b in range(n_batches):
            bs_s = rng.integers(0, V, bs).astype(np.uint32)
            bs_d = rng.integers(0, V, bs).astype(np.uint32)
            bw = rng.uniform(0.5, 4.0, bs).astype(np.float32)
            g = ensure_capacity(g, bs + 64)
            g, _ = insert_edges(g, pad(bs_s, bs), pad(bs_d, bs),
                                jnp.asarray(bw) if weighted else None)
            mask = jnp.ones(bs, bool)
            if weighted:
                t_dyn += time_fn(lambda: inc_fn(
                    g, state, pad(bs_s, bs), pad(bs_d, bs), jnp.asarray(bw),
                    mask, edge_capacity=cap), iters=3, warmup=1)
            else:
                t_dyn += time_fn(lambda: inc_fn(
                    g, state, pad(bs_s, bs), pad(bs_d, bs), mask,
                    edge_capacity=cap), iters=3, warmup=1)
            t_static += time_fn(lambda: static_fn(g, 0, edge_capacity=cap),
                                iters=3, warmup=1)
            if weighted:
                state, _ = inc_fn(g, state, pad(bs_s, bs), pad(bs_d, bs),
                                  jnp.asarray(bw), mask, edge_capacity=cap)
            else:
                state, _ = inc_fn(g, state, pad(bs_s, bs), pad(bs_d, bs),
                                  mask, edge_capacity=cap)
        row(f"{algo}_incremental_s10", t_dyn / n_batches,
            f"speedup_vs_static={t_static / t_dyn:.2f}x")

        # ---- decremental ---------------------------------------------------
        g = from_edges_host(V, src, dst, w if weighted else None,
                            hashing=False, slack_slabs=64)
        state, _ = static_fn(g, 0, edge_capacity=cap)
        dec_fn = sssp_decremental if weighted else bfs_decremental
        t_static = t_dyn = 0.0
        perm = rng.permutation(E)
        for b in range(n_batches):
            idx = perm[b * bs:(b + 1) * bs]
            ds, dd = src[idx], dst[idx]
            g, _ = delete_edges(g, pad(ds, bs), pad(dd, bs))
            mask = jnp.ones(bs, bool)
            t_dyn += time_fn(lambda: dec_fn(
                g, state, pad(ds, bs), pad(dd, bs), mask, src=0,
                edge_capacity=cap), iters=3, warmup=1)
            t_static += time_fn(lambda: static_fn(g, 0, edge_capacity=cap),
                                iters=3, warmup=1)
            state, _ = dec_fn(g, state, pad(ds, bs), pad(dd, bs), mask,
                              src=0, edge_capacity=cap)
        row(f"{algo}_decremental_s10", t_dyn / n_batches,
            f"speedup_vs_static={t_static / t_dyn:.2f}x")
