"""HORNET-like baseline (paper §5/§6 comparison target), in JAX.

Hornet keeps each vertex's adjacency contiguous in a power-of-two block;
inserts that overflow a block migrate the adjacency to the next block size.
This baseline reproduces that object model on TPU arrays:

  * ``storage``   — one flat uint32 array of edge destinations
  * ``block_off`` / ``block_cap`` / ``degree`` per vertex
  * insert: in-place append where room remains; overflowing vertices migrate
    to freshly bump-allocated blocks of 2× size (vectorised copy)
  * delete: swap-with-last (Hornet compacts; no tombstones)
  * query: per-query block scan in 128-lane chunks

Used by the benchmarks as the insert/delete/query and traversal baseline —
the paper's speedup *ratios* vs Hornet are the claims under test.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

INVALID = jnp.uint32(0xFFFFFFFF)


@partial(jax.tree_util.register_dataclass,
         data_fields=["storage", "block_off", "block_cap", "degree",
                      "alloc_ptr"],
         meta_fields=["n_vertices"])
@dataclasses.dataclass(frozen=True)
class HornetGraph:
    storage: jnp.ndarray     # (cap_total,) uint32
    block_off: jnp.ndarray   # (V,) int32
    block_cap: jnp.ndarray   # (V,) int32 (power of two)
    degree: jnp.ndarray      # (V,) int32
    alloc_ptr: jnp.ndarray   # () int32
    n_vertices: int


def _next_pow2(x: np.ndarray) -> np.ndarray:
    return np.maximum(1, 2 ** np.ceil(np.log2(np.maximum(x, 1)))) \
        .astype(np.int64)


def from_edges_host(n_vertices: int, src: np.ndarray, dst: np.ndarray,
                    *, slack: float = 2.0) -> HornetGraph:
    src = np.asarray(src, np.uint32)
    dst = np.asarray(dst, np.uint32)
    key = src.astype(np.uint64) << np.uint64(32) | dst
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    src, dst = src[idx], dst[idx]
    deg = np.bincount(src.astype(np.int64), minlength=n_vertices)
    cap = _next_pow2(deg)
    off = np.zeros(n_vertices + 1, np.int64)
    np.cumsum(cap, out=off[1:])
    total = int(off[-1] * slack) + 1024
    storage = np.full(total, np.uint32(INVALID), np.uint32)
    order = np.argsort(src, kind="stable")
    pos = off[src[order].astype(np.int64)] + \
        (np.arange(len(src)) - np.concatenate(
            [[0], np.cumsum(np.bincount(src.astype(np.int64),
                                        minlength=n_vertices))])[
            src[order].astype(np.int64)])
    # simpler rank computation
    rank = np.zeros(len(src), np.int64)
    counts = {}
    s_sorted = src[order]
    run_start = np.ones(len(src), bool)
    run_start[1:] = s_sorted[1:] != s_sorted[:-1]
    starts = np.maximum.accumulate(np.where(run_start,
                                            np.arange(len(src)), 0))
    rank = np.arange(len(src)) - starts
    storage[off[s_sorted.astype(np.int64)] + rank] = dst[order]
    return HornetGraph(
        storage=jnp.asarray(storage),
        block_off=jnp.asarray(off[:-1].astype(np.int32)),
        block_cap=jnp.asarray(cap.astype(np.int32)),
        degree=jnp.asarray(deg.astype(np.int32)),
        alloc_ptr=jnp.asarray(int(off[-1]), jnp.int32),
        n_vertices=n_vertices)


# ---------------------------------------------------------------------------
# query — per-query scan over the vertex's block, 128 lanes per hop
# ---------------------------------------------------------------------------

@jax.jit
def query_edges(g: HornetGraph, src: jnp.ndarray,
                dst: jnp.ndarray) -> jnp.ndarray:
    B = src.shape[0]
    valid = src != INVALID
    s = jnp.where(valid, src, 0).astype(jnp.int32)
    off = g.block_off[s]
    deg = jnp.where(valid, g.degree[s], 0)
    found = jnp.zeros((B,), bool)
    step = jnp.zeros((B,), jnp.int32)

    def cond(state):
        _, step, deg_left = state
        return jnp.any(step < deg_left)

    def body(state):
        found, step, deg_left = state
        lane = jnp.arange(128, dtype=jnp.int32)
        idx = off[:, None] + step[:, None] + lane[None, :]
        ok = (step[:, None] + lane[None, :]) < deg_left[:, None]
        vals = g.storage[jnp.minimum(idx, g.storage.shape[0] - 1)]
        hit = ok & (vals == dst[:, None])
        found = found | jnp.any(hit, axis=1)
        return found, step + 128, deg_left

    found, _, _ = jax.lax.while_loop(cond, body, (found, step, deg))
    return found & valid


# ---------------------------------------------------------------------------
# insert — in-place append + 2× block migration for overflowing vertices
# ---------------------------------------------------------------------------

@jax.jit
def insert_edges(g: HornetGraph, src: jnp.ndarray, dst: jnp.ndarray
                 ) -> Tuple[HornetGraph, jnp.ndarray]:
    B = src.shape[0]
    valid = src != INVALID
    exists = query_edges(g, src, dst)
    s_raw = jnp.where(valid, src, 0).astype(jnp.int32)

    # in-batch dedup (sort by (src, dst))
    big = jnp.uint32(0xFFFFFFFF)
    order = jnp.lexsort((dst, jnp.where(valid, src, big)))
    ss, sd = s_raw[order], dst[order]
    v_s = valid[order] & ~exists[order]
    dup = jnp.zeros((B,), bool)
    if B > 1:
        dup = dup.at[1:].set((ss[1:] == ss[:-1]) & (sd[1:] == sd[:-1])
                             & v_s[1:] & v_s[:-1])
    new = v_s & ~dup

    seg = jnp.where(new, ss, g.n_vertices)
    cnt = jax.ops.segment_sum(new.astype(jnp.int32), seg,
                              num_segments=g.n_vertices + 1)[:g.n_vertices]
    idx = jnp.cumsum(new.astype(jnp.int32)) - new.astype(jnp.int32)
    run_start = jnp.ones((B,), bool)
    if B > 1:
        run_start = run_start.at[1:].set(ss[1:] != ss[:-1])
    base = jax.lax.cummax(jnp.where(run_start, idx, -1))
    rank = jnp.where(new, idx - base, 0)

    # migration: vertices whose new degree exceeds capacity get a fresh
    # block of next_pow2(new_deg), bump-allocated
    new_deg = g.degree + cnt
    need = new_deg > g.block_cap
    new_cap = jnp.where(need,
                        jnp.maximum(g.block_cap * 2,
                                    1 << jnp.ceil(jnp.log2(
                                        jnp.maximum(new_deg, 1).astype(
                                            jnp.float32))).astype(jnp.int32)),
                        g.block_cap)
    grow = jnp.where(need, new_cap, 0)
    new_off_base = g.alloc_ptr + jnp.cumsum(grow) - grow
    block_off = jnp.where(need, new_off_base, g.block_off)
    block_cap = new_cap

    # copy migrated adjacencies (chunked over 128 lanes like query)
    storage = g.storage

    def cond(state):
        _, step = state
        active = need & (step < g.degree)
        return jnp.any(active)

    def body(state):
        storage, step = state
        lane = jnp.arange(128, dtype=jnp.int32)
        pos = step[:, None] + lane[None, :]
        ok = need[:, None] & (pos < g.degree[:, None])
        old_idx = g.block_off[:, None] + pos
        vals = g.storage[jnp.minimum(old_idx, g.storage.shape[0] - 1)]
        new_idx = jnp.where(ok, block_off[:, None] + pos,
                            storage.shape[0])
        storage = storage.at[new_idx.reshape(-1)].set(
            vals.reshape(-1), mode="drop")
        return storage, step + 128

    storage, _ = jax.lax.while_loop(
        cond, body, (storage, jnp.zeros((g.n_vertices,), jnp.int32)))

    # append new edges at degree + rank
    wr = jnp.where(new,
                   block_off[ss] + g.degree[ss] + rank,
                   storage.shape[0])
    storage = storage.at[wr].set(sd, mode="drop")

    inserted = jnp.zeros((B,), bool).at[order].set(new)
    g2 = dataclasses.replace(
        g, storage=storage, block_off=block_off, block_cap=block_cap,
        degree=new_deg, alloc_ptr=g.alloc_ptr + jnp.sum(grow))
    return g2, inserted


@jax.jit
def delete_edges(g: HornetGraph, src: jnp.ndarray, dst: jnp.ndarray
                 ) -> Tuple[HornetGraph, jnp.ndarray]:
    """Swap-with-last removal (Hornet compaction semantics), one edge per
    batch lane; duplicate (src,dst) lanes deduped first."""
    B = src.shape[0]
    valid = src != INVALID
    big = jnp.uint32(0xFFFFFFFF)
    order = jnp.lexsort((dst, jnp.where(valid, src, big)))
    ss = jnp.where(valid, src, 0).astype(jnp.int32)[order]
    sd = dst[order]
    v_s = valid[order]
    dup = jnp.zeros((B,), bool)
    if B > 1:
        dup = dup.at[1:].set((ss[1:] == ss[:-1]) & (sd[1:] == sd[:-1]))
    cand = v_s & ~dup

    # find position of each target within its block
    off = g.block_off[ss]
    deg = g.degree[ss]
    pos = jnp.full((B,), -1, jnp.int32)
    step = jnp.zeros((B,), jnp.int32)

    def cond(state):
        _, step = state
        return jnp.any(step < deg)

    def body(state):
        pos, step = state
        lane = jnp.arange(128, dtype=jnp.int32)
        p = step[:, None] + lane[None, :]
        ok = cand[:, None] & (p < deg[:, None])
        vals = g.storage[jnp.minimum(off[:, None] + p,
                                     g.storage.shape[0] - 1)]
        hit = ok & (vals == sd[:, None]) & (pos[:, None] < 0)
        first = jnp.argmax(hit, axis=1).astype(jnp.int32)
        pos = jnp.where(jnp.any(hit, axis=1) & (pos < 0),
                        step + first, pos)
        return pos, step + 128

    pos, _ = jax.lax.while_loop(cond, body, (pos, step))
    hit = cand & (pos >= 0)

    # multiple deletes within one vertex's block: resolve sequentially by
    # rank — handle the common benchmark case (distinct vertices / edges)
    last_val = g.storage[jnp.minimum(off + deg - 1, g.storage.shape[0] - 1)]
    wr = jnp.where(hit, off + pos, g.storage.shape[0])
    storage = g.storage.at[wr].set(last_val, mode="drop")
    tail = jnp.where(hit, off + deg - 1, g.storage.shape[0])
    storage = storage.at[tail].set(INVALID, mode="drop")

    seg = jnp.where(hit, ss, g.n_vertices)
    dec = jax.ops.segment_sum(hit.astype(jnp.int32), seg,
                              num_segments=g.n_vertices + 1)[:g.n_vertices]
    deleted = jnp.zeros((B,), bool).at[order].set(hit)
    return dataclasses.replace(g, storage=storage, degree=g.degree - dec), \
        deleted


def csr_view(g: HornetGraph):
    """CSR arrays for traversal baselines (indptr via degrees)."""
    return g.block_off, g.degree, g.storage


def nbytes(g: HornetGraph) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(g))
