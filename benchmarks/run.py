"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` scales graphs up;
the default 'quick' profile keeps the whole suite CPU-friendly.  The paper's
claims are *ratios* (vs baseline / vs static recompute); absolute times on
this CPU container are not comparable with the paper's RTX 2080 Ti.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every suite's structured rows "
                         "(timing.take_rows) as one JSON artifact")
    ap.add_argument("--check", action="store_true",
                    help="perf-regression gate: snapshot the committed "
                         "BENCH_*.json baselines before the suites "
                         "overwrite them, diff the fresh artifacts after "
                         "(benchmarks.regress), exit 1 on regression")
    args = ap.parse_args()
    scale = "full" if args.full else "quick"

    from . import (chaos_bench, churn_bench, dynamic_speedup, memory_table,
                   pagerank_bench, serve_bench, sharded_bench, sweep_bench,
                   traversal, triangle_bench, update_bench,
                   update_throughput, wcc_bench)
    suites = {
        "memory_table": memory_table,        # Table 5
        "update_throughput": update_throughput,  # Figs 3–5
        "traversal": traversal,              # Fig 6
        "dynamic_speedup": dynamic_speedup,  # Fig 7
        "pagerank": pagerank_bench,          # Figs 8–10
        "triangle": triangle_bench,          # Fig 11
        "wcc": wcc_bench,                    # Fig 12 + Table 6
        "sweep": sweep_bench,                # old-path vs slab-sweep engine
        "serve": serve_bench,                # legacy loop vs repro.stream
        "update": update_bench,              # Fig 5 old-path vs update engine
        "sharded": sharded_bench,            # 8-device sharded stream plane
        "churn": churn_bench,                # maintenance plane under churn
        "chaos": chaos_bench,                # fault injection + WAL recovery
    }
    from . import timing
    only = set(args.only.split(",")) if args.only else None
    baselines = None
    if args.check:
        # MUST snapshot before any suite runs: each suite overwrites its
        # committed artifact in place
        from . import regress
        baselines = regress.snapshot_baselines(only)
    print("name,us_per_call,derived")
    failed = []
    rows = {}
    timing.take_rows()                       # drop any import-time strays
    for name, mod in suites.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            mod.run(scale)
        except Exception:
            traceback.print_exc()
            failed.append(name)
        rows[name] = timing.take_rows()
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({"scale": scale, "suites": rows}, f, indent=2)
        print(f"# structured rows -> {args.json}")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    if baselines is not None:
        from . import regress
        ran = [n for n in suites if not only or n in only]
        if not regress.report(regress.check(baselines, ran)):
            print("# PERF REGRESSION — see regress FAIL rows above")
            sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
