"""Chaos bench: availability and crash recovery under scripted faults.

One churn workload, three measured regimes over the full resilience plane
(WAL + audits + admission guard + circuit breaker, DESIGN.md §11):

* **calm**      — the plane armed, zero faults: proves no-fault neutrality
  (pools bit-identical to a store running with nothing attached) and
  prices the WAL/audit overhead;
* **storm**     — corrupt batches (``faults.corrupt_batch``) and injected
  OOM bursts hit a breaker-guarded ``RequestPipeline``: measures request
  availability (non-error responses / total) and how many update groups
  the breaker sheds while reads keep serving;
* **crashes**   — a scripted kill at every instrumented apply phase, each
  followed by ``resilience.recover`` (checkpoint restore + WAL-suffix
  replay) and stream re-feed: measures recovery latency (seconds and
  replayed epochs) and asserts the recovered pools converge bit-identical
  to an uninterrupted oracle.

Results land in ``BENCH_chaos.json``; the bit-identity and availability
flags are asserted, so CI's chaos-smoke step fails loudly if resilience
regresses.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro import resilience as rz
from repro.algorithms import pagerank_stream_property
from repro.resilience import faults
from repro.stream import (GraphStore, MaintenancePolicy, PropertyRegistry,
                          RequestPipeline, UpdateBatch, PropertyRead)

from .timing import row

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_chaos.json"

APPLY_SITES = ("apply.admitted", "store.capacity_grow", "apply.post_wal",
               "apply.pre_close", "apply.post_close")


def _stream(seed, V, n_batches, *, n_ins, n_del):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, V, n_ins).astype(np.uint32),
             rng.integers(0, V, n_ins).astype(np.uint32),
             rng.integers(0, V, n_del).astype(np.uint32),
             rng.integers(0, V, n_del).astype(np.uint32))
            for _ in range(n_batches)]


def _leaves(store):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(store.views)]


def _identical(a, b):
    return len(a) == len(b) and all(
        x.shape == y.shape and np.array_equal(x, y) for x, y in zip(a, b))


def _mk_store(V, seed, maintenance):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, V, 6 * V).astype(np.uint32)
    dst = rng.integers(0, V, 6 * V).astype(np.uint32)
    return GraphStore.from_edges(V, src, dst, maintenance=maintenance)


# ---------------------------------------------------------------------------
# regime 1: calm — neutrality + plane overhead
# ---------------------------------------------------------------------------

def calm(V, batches, tmp, maintenance):
    def drive(resilient):
        store = _mk_store(V, 11, maintenance)
        if resilient:
            store.attach_wal(rz.WriteAheadLog(tmp / "wal_calm"))
            store.attach_audits(rz.AuditPolicy(every=4, fail_fast=True))
        t0 = time.perf_counter()
        for i_s, i_d, d_s, d_d in batches:
            store.apply(i_s, i_d, None, d_s, d_d)
        dt = time.perf_counter() - t0
        if resilient:
            store.wal.close()
        return _leaves(store), dt

    drive(False)                             # warmup: compile every kernel
    base, t_plain = drive(False)
    armed, t_armed = drive(True)
    return {
        "no_fault_bit_identical": _identical(base, armed),
        "epoch_ms_plain": round(1e3 * t_plain / len(batches), 3),
        "epoch_ms_armed": round(1e3 * t_armed / len(batches), 3),
        "overhead_x": round(t_armed / t_plain, 3),
    }


# ---------------------------------------------------------------------------
# regime 2: storm — corrupt batches + OOM bursts against the breaker
# ---------------------------------------------------------------------------

def storm(V, batches, maintenance):
    store = _mk_store(V, 11, maintenance)
    registry = PropertyRegistry(store)
    registry.register(pagerank_stream_property())
    pipe = RequestPipeline(store, registry, coalesce=False,
                           breaker=rz.CircuitBreaker(threshold=3, cooldown=4))
    rng = np.random.default_rng(5)
    requests = []
    for t, (i_s, i_d, d_s, d_d) in enumerate(batches * 3):
        # bursts of 3 consecutive corrupt batches (= breaker threshold):
        # each burst trips it, the following good updates are shed through
        # the cooldown, then a half-open probe closes it again
        if t % 8 in (5, 6, 7):
            mode = faults.CORRUPTION_MODES[t % len(faults.CORRUPTION_MODES)]
            c_s, c_d, c_w = faults.corrupt_batch(
                rng, i_s, i_d, mode=mode, n_vertices=V, lanes=2)
            requests.append(UpdateBatch(ins_src=c_s, ins_dst=c_d, ins_w=c_w))
        else:
            requests.append(UpdateBatch(ins_src=i_s, ins_dst=i_d,
                                        del_src=d_s, del_dst=d_d))
        requests.append(PropertyRead("pagerank"))

    t0 = time.perf_counter()
    responses = pipe.run(requests)
    dt = time.perf_counter() - t0
    ok = sum(1 for r in responses if r.kind != "error")
    stale = sum(1 for r in responses
                if r.kind == "property" and r.payload.get("stale"))
    return {
        "requests": len(requests),
        "served_ok": ok,
        "availability_pct": round(100.0 * ok / len(requests), 2),
        "breaker": pipe.breaker.status(),
        "stale_property_serves": stale,
        "final_version": store.version,
    }


# ---------------------------------------------------------------------------
# regime 2b: burn storm — latency-SLO violations trip the breaker without
# a single failed request (obs.health burn-rate shedding, ISSUE 10)
# ---------------------------------------------------------------------------

def burn_storm(V, batches, maintenance):
    """Injected LATENCY faults slow every apply far past the declared SLO:
    nothing ever throws, so a failure-count breaker would never trip —
    the HealthEngine's error-budget burn rate must do it instead."""
    from repro.obs.health import HealthEngine, SLOTarget
    store = _mk_store(V, 11, maintenance)
    registry = PropertyRegistry(store)
    registry.register(pagerank_stream_property())
    engine = HealthEngine(
        [SLOTarget("update", latency_s=0.005, objective=0.5)], window=16)
    breaker = rz.CircuitBreaker(threshold=99, cooldown=3,
                                burn_threshold=1.5)
    pipe = RequestPipeline(store, registry, coalesce=False, breaker=breaker,
                           health=engine, health_every=4)
    requests = []
    for i_s, i_d, d_s, d_d in batches * 2:
        requests.append(UpdateBatch(ins_src=i_s, ins_dst=i_d,
                                    del_src=d_s, del_dst=d_d))
        requests.append(PropertyRead("pagerank"))
    with faults.inject(rz.FaultSpec("apply.admitted", kind=faults.LATENCY,
                                    every=1, times=0, delay_s=0.02)):
        responses = pipe.run(requests)
    shed = sum(1 for r in responses if r.payload.get("shed"))
    ok = sum(1 for r in responses if r.kind != "error")
    report = engine.report()
    return {
        "requests": len(requests),
        "served_ok": ok,
        "shed_groups": shed,
        "breaker": breaker.status(),
        "worst_burn": round(report.worst_burn, 2),
        "update_slo_ms": 5.0,
        "final_version": store.version,
    }


# ---------------------------------------------------------------------------
# regime 3: crashes — kill at every apply phase, recover, converge
# ---------------------------------------------------------------------------

def crashes(V, batches, tmp, maintenance, *, ckpt_at=2, crash_at=5):
    oracle = _mk_store(V, 11, maintenance)
    vers = []
    for i_s, i_d, d_s, d_d in batches:
        oracle.apply(i_s, i_d, None, d_s, d_d)
        vers.append(oracle.version)
    want = _leaves(oracle)

    runs = []
    for site in APPLY_SITES:
        ck, wd = tmp / f"ck_{site}", tmp / f"wal_{site}"
        store = _mk_store(V, 11, maintenance).attach_wal(
            rz.WriteAheadLog(wd))
        registry = PropertyRegistry(store)
        registry.register(pagerank_stream_property())
        try:
            for t, (i_s, i_d, d_s, d_d) in enumerate(batches):
                if t == ckpt_at:
                    store.save(ck, registry=registry)
                if t == crash_at:
                    with faults.inject(rz.FaultSpec(site, at=1)):
                        store.apply(i_s, i_d, None, d_s, d_d)
                else:
                    store.apply(i_s, i_d, None, d_s, d_d)
        except rz.InjectedCrash:
            pass
        store.wal.close()

        t0 = time.perf_counter()
        store2, _, report = rz.recover(
            ck, wd, specs=[pagerank_stream_property()],
            maintenance=maintenance, wal=rz.WriteAheadLog(wd))
        t_recover = time.perf_counter() - t0
        resume = vers.index(store2.version) + 1
        for i_s, i_d, d_s, d_d in batches[resume:]:
            store2.apply(i_s, i_d, None, d_s, d_d)
        runs.append({
            "site": site,
            "recover_s": round(t_recover, 3),
            "replayed_epochs": report.replayed,
            "lost_in_flight": resume == crash_at,
            "bit_identical": _identical(_leaves(store2), want),
        })
    return runs


def run(scale: str = "quick"):
    import tempfile
    V, n_batches, n_ins, n_del = ((256, 8, 120, 24) if scale == "quick"
                                  else (2048, 16, 1024, 256))
    maintenance = MaintenancePolicy(tombstone_ratio=0.15)
    batches = _stream(23, V, n_batches, n_ins=n_ins, n_del=n_del)

    with tempfile.TemporaryDirectory() as td:
        tmp = pathlib.Path(td)
        calm_r = calm(V, batches, tmp, maintenance)
        storm_r = storm(V, batches, maintenance)
        burn_r = burn_storm(V, batches, maintenance)
        crash_r = crashes(V, batches, tmp, maintenance)

    assert calm_r["no_fault_bit_identical"], \
        "resilience plane armed with zero faults must be pool-neutral"
    assert all(r["bit_identical"] for r in crash_r), \
        f"crash recovery diverged: {crash_r}"
    assert storm_r["availability_pct"] > 50.0, storm_r
    assert burn_r["breaker"]["burn_trips"] >= 1, \
        f"burn-rate shedding never engaged: {burn_r}"
    assert burn_r["shed_groups"] >= 1, burn_r

    row("chaos_calm_overhead", calm_r["epoch_ms_armed"] * 1e3,
        f"overhead={calm_r['overhead_x']}x;neutral="
        f"{calm_r['no_fault_bit_identical']}")
    row("chaos_storm", 0.0,
        f"avail={storm_r['availability_pct']}%;"
        f"trips={storm_r['breaker']['trips']};"
        f"shed={storm_r['breaker']['shed']}")
    row("chaos_burn_storm", 0.0,
        f"burn={burn_r['worst_burn']};"
        f"burn_trips={burn_r['breaker']['burn_trips']};"
        f"shed={burn_r['shed_groups']}")
    for r in crash_r:
        row(f"chaos_recover_{r['site']}", r["recover_s"] * 1e6,
            f"replayed={r['replayed_epochs']};identical={r['bit_identical']}")

    payload = {
        "backend": jax.default_backend(),
        "scale": scale,
        "graph": {"V": V, "batches": n_batches,
                  "ins_per_batch": n_ins, "del_per_batch": n_del},
        "calm": calm_r,
        "storm": storm_r,
        "burn_storm": burn_r,
        "crashes": crash_r,
        "note": ("calm = plane armed, zero faults (neutrality + overhead); "
                 "storm = corrupt batches + breaker (availability); "
                 "burn_storm = injected latency blows the update SLO "
                 "without a single failure -> health burn rate trips the "
                 "breaker; crashes = kill at each apply phase -> "
                 "recover() -> re-feed, bit-identity asserted vs "
                 "uninterrupted oracle."),
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("chaos_bench_json", 0.0, str(_OUT.name))
