"""Benchmark timing helpers (CPU wall-clock; claims are ratios, not absolutes)."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            **kw) -> float:
    """Median wall-time in microseconds (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
