"""Benchmark timing helpers (CPU wall-clock; claims are ratios, not absolutes).

Rebased on the telemetry plane's ``repro.obs.metrics.Histogram``: every
timed call lands in a private histogram, so besides the median the suites
get exact p10/p90 spread for free, and every ``row()`` is kept as a
structured dict (``take_rows()``) that ``benchmarks/run.py`` folds into
one JSON artifact next to the CSV stream.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax

from repro.obs.metrics import Histogram

#: structured row accumulator — one dict per row() call, drained by
#: take_rows() (benchmarks/run.py writes them to BENCH_rows.json)
ROWS: List[Dict] = []


def time_stats(fn: Callable, *args, iters: int = 5, warmup: int = 2,
               **kw) -> Dict[str, float]:
    """Wall-time distribution in microseconds (after jit warmup):
    ``{median_us, p10_us, p90_us, mean_us, iters}`` from an exact
    per-iteration histogram."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    h = Histogram()
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        h.record(time.perf_counter() - t0)
    return {"median_us": h.percentile(50) * 1e6,
            "p10_us": h.percentile(10) * 1e6,
            "p90_us": h.percentile(90) * 1e6,
            "mean_us": h.mean * 1e6,
            "iters": iters}


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2,
            **kw) -> float:
    """Median wall-time in microseconds (after jit warmup)."""
    return time_stats(fn, *args, iters=iters, warmup=warmup, **kw)["median_us"]


def row(name: str, us: float, derived: str = "",
        stats: Optional[Dict[str, float]] = None) -> str:
    """Print one CSV row AND retain it structured (with the optional
    ``time_stats`` spread) for the consolidated JSON output."""
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    rec = {"name": name, "us_per_call": round(float(us), 1),
           "derived": derived}
    if stats is not None:
        rec.update({k: round(float(v), 1) for k, v in stats.items()})
    ROWS.append(rec)
    return line


def take_rows() -> List[Dict]:
    """Drain and return every structured row recorded since the last call."""
    out = list(ROWS)
    ROWS.clear()
    return out
