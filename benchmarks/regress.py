"""Perf-regression gate — diff fresh bench runs against committed baselines.

Every bench suite OVERWRITES its ``BENCH_*.json`` artifact at the repo
root, so the committed file IS the baseline — until the suite runs.  The
gate therefore works in two phases around a ``benchmarks.run --check``
invocation:

1. :func:`snapshot_baselines` parses the committed artifacts into memory
   BEFORE any suite runs (the on-disk files are about to be clobbered);
2. after the suites overwrite them, :func:`check` re-reads the fresh
   artifacts and compares metric-by-metric against the snapshot.

Comparison model — per-metric :class:`MetricSpec` with a direction and a
multiplicative noise tolerance:

* ``higher`` (throughputs, speedups): regressed when
  ``fresh < baseline * floor`` — the floor is generous (default 0.45x)
  because quick-scale runs on a shared CPU container are noisy;
* ``lower`` (latencies): regressed when ``fresh > baseline * ceil``
  (default 1.9x — deliberately under 2x, so a genuine 2x latency
  regression ALWAYS fails the gate; the self-test pins that);
* ``equal`` (deterministic invariants: bit-identity flags, agreed
  triangle counts, capacity trajectories): any drift regresses.

Percentile metrics additionally carry a ``samples`` guard: with fewer
than ``min_samples`` requests behind a p95/p99 the comparison is SKIPPED
(recorded, not failed) — a tail estimated from 4 samples is an anecdote,
not a metric.  ``serve_bench`` records the per-class sample count next to
every percentile for exactly this reason.

Suites whose fresh run used a different ``scale``/``backend`` than the
committed baseline are skipped whole — cross-scale ratios are not
comparable.

CLI::

    python -m benchmarks.run --only serve --check   # gate a fresh run
    python -m benchmarks.regress --selftest         # prove the gate trips
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
from typing import Any, Dict, List, Optional, Sequence

_ROOT = pathlib.Path(__file__).resolve().parent.parent

#: suite name (benchmarks.run key) -> committed artifact
BENCH_FILES: Dict[str, str] = {
    "serve": "BENCH_serve.json",
    "sweep": "BENCH_sweep.json",
    "update": "BENCH_update.json",
    "churn": "BENCH_churn.json",
    "triangle": "BENCH_triangle.json",
    "sharded": "BENCH_sharded.json",
    "chaos": "BENCH_chaos.json",
}

#: defaults: floor for higher-is-better, ceil for lower-is-better
HIGHER_FLOOR = 0.45
LOWER_CEIL = 1.9
MIN_SAMPLES = 16


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One gated metric.  ``path`` is dotted; a segment that hits a LIST
    either selects the element whose ``name`` field matches the segment,
    or ``*`` to assert over every element."""
    suite: str
    path: str
    direction: str                     # "higher" | "lower" | "equal"
    tolerance: Optional[float] = None  # ratio vs baseline (None = default)
    samples_path: Optional[str] = None  # sibling sample-count guard
    min_samples: int = MIN_SAMPLES

    def limit(self) -> float:
        if self.tolerance is not None:
            return self.tolerance
        return HIGHER_FLOOR if self.direction == "higher" else LOWER_CEIL


SPECS: List[MetricSpec] = [
    # -- serve: throughput ratios + per-class latency tails -----------------
    MetricSpec("serve", "requests_per_sec.stream_insert_only", "higher"),
    MetricSpec("serve", "requests_per_sec.stream_mixed_del25", "higher"),
    MetricSpec("serve", "speedup_insert_only", "higher"),
    MetricSpec("serve", "open_loop.achieved_req_per_s", "higher"),
    MetricSpec("serve", "latency_ms.update.p95", "lower",
               samples_path="latency_ms.update.samples"),
    MetricSpec("serve", "latency_ms.property.p95", "lower",
               samples_path="latency_ms.property.samples"),
    MetricSpec("serve", "latency_ms.member.p95", "lower",
               samples_path="latency_ms.member.samples"),
    MetricSpec("serve", "latency_ms.update.mean", "lower",
               samples_path="latency_ms.update.samples", min_samples=4),
    # -- sweep: engine-vs-old-path speedups ---------------------------------
    MetricSpec("sweep", "results.bfs.speedup", "higher"),
    MetricSpec("sweep", "results.sssp.speedup", "higher"),
    MetricSpec("sweep", "results.wcc.speedup", "higher"),
    # -- update: stream-path speedups ---------------------------------------
    MetricSpec("update", "results.mixed_stream_b2048.speedup", "higher"),
    MetricSpec("update", "results.insert_stream_b8192.speedup", "higher"),
    MetricSpec("update", "results.delete_stream_b8192.speedup", "higher"),
    # -- churn: the maintenance plane's capacity bound is DETERMINISTIC -----
    MetricSpec("churn", "results.capacity_slabs.maintained", "equal"),
    MetricSpec("churn", "results.capacity_slabs.unmaintained", "equal"),
    # -- triangle: count identity + dynamic-vs-recount ----------------------
    MetricSpec("triangle", "results.engines_agree", "equal"),
    MetricSpec("triangle", "results.triangles", "equal"),
    MetricSpec("triangle", "results.incremental.delta_matches_recount",
               "equal"),
    MetricSpec("triangle", "results.incremental.speedup_vs_recount",
               "higher"),
    MetricSpec("triangle", "results.decremental.speedup_vs_recount",
               "higher"),
    # -- sharded ------------------------------------------------------------
    MetricSpec("sharded", "results.store_apply_8shard_vs_1shard.speedup",
               "higher"),
    # -- chaos: resilience invariants + availability under storm ------------
    MetricSpec("chaos", "calm.no_fault_bit_identical", "equal"),
    MetricSpec("chaos", "crashes.*.bit_identical", "equal"),
    MetricSpec("chaos", "storm.availability_pct", "higher",
               tolerance=0.5),
    # the black-box neutrality bound: flight-recorder overhead on the
    # closed-loop mixed serve must stay measured-bounded (ISSUE 10)
    MetricSpec("serve", "flight_overhead_x", "lower", tolerance=None),
]


# ---------------------------------------------------------------------------
# metric resolution
# ---------------------------------------------------------------------------

class _Missing:
    def __repr__(self):                              # pragma: no cover
        return "<missing>"


MISSING = _Missing()


def resolve(doc: Any, path: str) -> Any:
    """Walk ``doc`` along the dotted ``path`` (module doc for list
    semantics).  Returns :data:`MISSING` when the path dead-ends; a ``*``
    over a list returns the list of per-element resolutions."""
    node = doc
    parts = path.split(".")
    for i, part in enumerate(parts):
        if isinstance(node, dict):
            if part not in node:
                return MISSING
            node = node[part]
        elif isinstance(node, list):
            if part == "*":
                rest = ".".join(parts[i + 1:])
                return [resolve(el, rest) if rest else el for el in node]
            named = [el for el in node
                     if isinstance(el, dict) and el.get("name") == part]
            if not named:
                return MISSING
            node = named[0]
        else:
            return MISSING
    return node


# ---------------------------------------------------------------------------
# comparison
# ---------------------------------------------------------------------------

def _compare_scalar(spec: MetricSpec, base: Any, fresh: Any) -> str:
    if spec.direction == "equal":
        return "ok" if base == fresh else "regressed"
    try:
        b, f = float(base), float(fresh)
    except (TypeError, ValueError):
        return "regressed"
    if spec.direction == "higher":
        return "ok" if f >= b * spec.limit() else "regressed"
    return "ok" if f <= b * spec.limit() else "regressed"


def compare_metric(spec: MetricSpec, baseline_doc: dict,
                   fresh_doc: dict) -> Dict[str, Any]:
    """One spec against one (baseline, fresh) suite pair; returns the
    structured row the report prints."""
    row: Dict[str, Any] = {"suite": spec.suite, "metric": spec.path,
                           "direction": spec.direction,
                           "limit": spec.limit()}
    base = resolve(baseline_doc, spec.path)
    fresh = resolve(fresh_doc, spec.path)
    row["baseline"], row["fresh"] = \
        (None if base is MISSING else base), \
        (None if fresh is MISSING else fresh)
    if base is MISSING:
        # schema drift forward: the committed baseline predates this
        # metric — record, don't fail (the next baseline refresh arms it)
        row["status"] = "skipped_no_baseline"
        return row
    if fresh is MISSING:
        # coverage regression: the fresh run LOST a gated metric
        row["status"] = "regressed"
        row["why"] = "metric missing from fresh run"
        return row
    if spec.samples_path is not None:
        ns = [resolve(d, spec.samples_path)
              for d in (baseline_doc, fresh_doc)]
        counts = [0 if n is MISSING else int(n) for n in ns]
        if min(counts) < spec.min_samples:
            row["status"] = "skipped_low_samples"
            row["samples"] = counts
            return row
    if isinstance(base, list) or isinstance(fresh, list):
        if not isinstance(base, list) or not isinstance(fresh, list) \
                or len(base) != len(fresh):
            row["status"] = "regressed"
            row["why"] = "element count drift"
            return row
        verdicts = [_compare_scalar(spec, b, f)
                    for b, f in zip(base, fresh)]
        row["status"] = ("ok" if all(v == "ok" for v in verdicts)
                         else "regressed")
        return row
    row["status"] = _compare_scalar(spec, base, fresh)
    return row


# ---------------------------------------------------------------------------
# the two-phase gate
# ---------------------------------------------------------------------------

def snapshot_baselines(suites: Optional[Sequence[str]] = None
                       ) -> Dict[str, dict]:
    """Parse the committed artifacts into memory (call BEFORE any suite
    runs — they overwrite their files)."""
    out: Dict[str, dict] = {}
    for suite, fname in BENCH_FILES.items():
        if suites is not None and suite not in suites:
            continue
        path = _ROOT / fname
        try:
            out[suite] = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            pass                       # no baseline yet: nothing to gate
    return out


def check(baselines: Dict[str, dict],
          suites: Optional[Sequence[str]] = None,
          fresh: Optional[Dict[str, dict]] = None) -> List[Dict[str, Any]]:
    """Compare fresh artifacts (re-read from disk unless passed in)
    against the snapshot; returns every comparison row."""
    rows: List[Dict[str, Any]] = []
    for spec in SPECS:
        if suites is not None and spec.suite not in suites:
            continue
        base_doc = baselines.get(spec.suite)
        if base_doc is None:
            continue
        if fresh is not None and spec.suite in fresh:
            fresh_doc = fresh[spec.suite]
        else:
            try:
                fresh_doc = json.loads(
                    (_ROOT / BENCH_FILES[spec.suite]).read_text())
            except (OSError, json.JSONDecodeError):
                rows.append({"suite": spec.suite, "metric": spec.path,
                             "status": "regressed",
                             "why": "fresh artifact unreadable"})
                continue
        for key in ("scale", "backend"):
            if base_doc.get(key) != fresh_doc.get(key):
                rows.append({"suite": spec.suite, "metric": spec.path,
                             "status": f"skipped_{key}_mismatch",
                             "baseline": base_doc.get(key),
                             "fresh": fresh_doc.get(key)})
                break
        else:
            rows.append(compare_metric(spec, base_doc, fresh_doc))
    return rows


def report(rows: List[Dict[str, Any]], *, out=sys.stdout) -> bool:
    """Print the gate verdict; True when no metric regressed."""
    regressed = [r for r in rows if r["status"] == "regressed"]
    for r in rows:
        mark = {"ok": "PASS", "regressed": "FAIL"}.get(r["status"], "skip")
        detail = ""
        if r.get("baseline") is not None and r.get("fresh") is not None:
            detail = f"  base={r['baseline']} fresh={r['fresh']}" \
                     f" limit={r.get('limit', '')}x"
        why = f"  ({r['why']})" if r.get("why") else ""
        print(f"# regress {mark:4s} {r['suite']}.{r['metric']}"
              f"{detail}{why}", file=out)
    print(f"# regress: {len(rows)} gated, "
          f"{sum(1 for r in rows if r['status'] == 'ok')} pass, "
          f"{len(regressed)} regressed, "
          f"{sum(1 for r in rows if r['status'].startswith('skip'))} "
          f"skipped", file=out)
    return not regressed


# ---------------------------------------------------------------------------
# self-test: the gate must trip on an injected 2x latency regression
# ---------------------------------------------------------------------------

def _inject_latency_regression(doc: dict, factor: float = 2.0) -> dict:
    bad = json.loads(json.dumps(doc))
    for cls in bad.get("latency_ms", {}).values():
        for k in ("mean", "p50", "p95", "p99"):
            if k in cls:
                cls[k] = cls[k] * factor
    return bad


def selftest() -> bool:
    """Three assertions: identity passes, a 2x latency regression fails,
    and a halved throughput fails.  Runs on the COMMITTED serve baseline
    (no suite executes)."""
    baselines = snapshot_baselines(["serve"])
    if "serve" not in baselines:
        print("# regress selftest: no committed serve baseline — skipped")
        return True
    base = baselines["serve"]
    ok = True
    # identity: a run identical to its baseline must pass
    rows = check(baselines, ["serve"], fresh={"serve": base})
    if any(r["status"] == "regressed" for r in rows):
        print("# regress selftest FAILED: identity comparison regressed")
        report(rows)
        ok = False
    # 2x latency: must fail (when sample counts clear the guard) — pin on
    # the mean gate, which arms at min_samples=4
    bad = _inject_latency_regression(base, 2.0)
    rows = check(baselines, ["serve"], fresh={"serve": bad})
    lat = [r for r in rows if r["metric"].startswith("latency_ms.")
           and r["status"] in ("regressed", "skipped_low_samples")]
    if not any(r["status"] == "regressed" for r in lat):
        print("# regress selftest FAILED: 2x latency regression "
              "not caught")
        report(rows)
        ok = False
    # halved throughput: must fail
    slow = json.loads(json.dumps(base))
    for k in slow["requests_per_sec"]:
        slow["requests_per_sec"][k] *= 0.25
    rows = check(baselines, ["serve"], fresh={"serve": slow})
    if not any(r["status"] == "regressed"
               and r["metric"].startswith("requests_per_sec")
               for r in rows):
        print("# regress selftest FAILED: 4x throughput drop not caught")
        report(rows)
        ok = False
    if ok:
        print("# regress selftest: identity passes, 2x latency + 4x "
              "throughput regressions trip the gate")
    return ok


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--selftest", action="store_true",
                    help="prove the gate trips on injected regressions")
    ap.add_argument("--suites", default=None,
                    help="comma-separated subset (default: all committed)")
    args = ap.parse_args(argv)
    if args.selftest:
        sys.exit(0 if selftest() else 1)
    # no-run mode: compare the artifacts on disk against themselves is
    # meaningless — standalone invocation only supports the selftest;
    # the live gate is `python -m benchmarks.run --check`.
    ap.error("use --selftest here, or `python -m benchmarks.run --check` "
             "to gate a fresh run")


if __name__ == "__main__":
    main()
