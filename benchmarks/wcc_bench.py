"""Paper Fig. 12 + Table 6 — WCC: static vs CSR-BFS baseline; incremental
schemes (naive / SlabIterator / UpdateIterator / UpdateIterator+SingleBucket)
across 2K/4K/8K batches."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.algorithms import (wcc_incremental_batch, wcc_incremental_naive,
                              wcc_incremental_slab_iterator,
                              wcc_incremental_update_iterator, wcc_static)
from repro.core import ensure_capacity, from_edges_host, insert_edges, \
    update_slab_pointers
from repro.data.synth import rmat_edges

from .timing import row, time_fn


def pad(a, n):
    out = np.full(n, 0xFFFFFFFF, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def run(scale: str = "quick"):
    V, E = (20000, 120000) if scale == "quick" else (200000, 1500000)
    src, dst = rmat_edges(V, E, seed=10)
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])

    g_hash = from_edges_host(V, s, d, hashing=True, slack_slabs=40000)
    g_flat = from_edges_host(V, s, d, hashing=False, slack_slabs=40000)

    us = time_fn(lambda: wcc_static(g_hash), iters=3)
    row("wcc_static_meerkat", us, f"V={V};E={len(s)}")

    rng = np.random.default_rng(11)
    for bs in (2048, 4096, 8192):
        bs_s = rng.integers(0, V, bs // 2).astype(np.uint32)
        bs_d = rng.integers(0, V, bs // 2).astype(np.uint32)
        b2s = np.concatenate([bs_s, bs_d])
        b2d = np.concatenate([bs_d, bs_s])
        results = {}
        for name, g0 in (("hash", g_hash), ("single_bucket", g_flat)):
            labels = wcc_static(g0)
            g = update_slab_pointers(g0)
            g = ensure_capacity(g, bs + 64)
            g, _ = insert_edges(g, pad(b2s, bs), pad(b2d, bs))
            slab_cap = 1 << 18   # touched-vertex adjacency budget
            upd_cap = 2 * bs     # update budget: ~batch size lanes
            t_naive = time_fn(lambda: wcc_incremental_naive(labels, g),
                              iters=3)
            t_slab = time_fn(
                lambda: wcc_incremental_slab_iterator(labels, g,
                                                      cap=slab_cap), iters=3)
            t_upd = time_fn(
                lambda: wcc_incremental_update_iterator(labels, g,
                                                        cap=upd_cap), iters=3)
            results[name] = (t_naive, t_slab, t_upd)
        t_naive, t_slab, t_upd = results["hash"]
        row(f"wcc_inc_naive_b{bs}", t_naive, "")
        row(f"wcc_inc_slabiter_b{bs}", t_slab,
            f"speedup_vs_naive={t_naive / t_slab:.2f}x")
        row(f"wcc_inc_upditer_b{bs}", t_upd,
            f"speedup_vs_naive={t_naive / t_upd:.2f}x")
        t_naive_sb, _, t_upd_sb = results["single_bucket"]
        row(f"wcc_inc_upditer_single_bucket_b{bs}", t_upd_sb,
            f"speedup_vs_naive={t_naive_sb / t_upd_sb:.2f}x")
