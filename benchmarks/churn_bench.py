"""Sustained insert+delete churn, with vs without the maintenance plane.

The paper's core dynamic-graph workload is a long stream of mixed update
batches.  The update plane is append-only (deletes tombstone, the bump
allocator only advances), so an unmaintained pool inflates monotonically
and every O(pool) slab sweep pays for the dead freight.  This bench runs
the SAME hub-skewed churn stream (hub-rooted inserts force real slab
allocation every epoch — the regime where chains actually grow) through
two ``GraphStore``s:

* **unmaintained** — the pre-§8 behaviour: tombstones accumulate,
  ``next_free`` only climbs, capacity ratchets up the pow2 ladder;
* **maintained** — a ``MaintenancePolicy`` compacts all views at epoch
  close when the tombstone ratio crosses the trigger, recycles freed
  slabs through the free list, and lets capacity walk back DOWN.

Asserted (the ISSUE-5 acceptance criteria, also covered in
tests/test_maintenance.py):

1. both stores agree with a host set-oracle ledger after the full stream
   (maintenance never changes results);
2. compacting the churned pool through the engine (jnp + pallas-interpret)
   is leaf-for-leaf identical to the ``impl="oracle"`` rebuild;
3. the maintained store ends with a strictly smaller pool capacity, and
   its allocator high-water mark stays bounded while the unmaintained one
   only climbs;
4. a slab-sweep over the compacted pool beats the tombstone-riddled pool.

Results land in ``BENCH_churn.json`` (and the CSV stream).
"""
from __future__ import annotations

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.slab_graph import pool_stats
from repro.kernels.slab_compact import compact
from repro.kernels.slab_sweep.ops import sweep_vertices
from repro.stream import GraphStore, MaintenancePolicy

from .timing import row

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_churn.json"


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _ab_times(fa, fb, *, iters: int = 11, warmup: int = 3):
    """Interleaved A/B medians (us) — alternating measurements cancel the
    slow clock/load drift that back-to-back ``time_fn`` blocks pick up."""
    import time
    for _ in range(warmup):
        jax.block_until_ready(fa())
        jax.block_until_ready(fb())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb())
        tb.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    return ta[iters // 2] * 1e6, tb[iters // 2] * 1e6


#: Destination keys draw from a much larger space than the vertex count —
#: legal because the update plane's dst guard is sentinel-based (the
#: sharded plane stores global ids the same way), and it keeps the pair
#: space effectively unbounded so inserts never saturate into duplicate
#: rejections: every epoch genuinely consumes fresh lanes, the way a
#: production stream with a large key universe does.
KEY_SPACE = 2 ** 20


def _hub_stream(rng, *, n_hubs, n_epochs, batch, delete_frac, ledger):
    """Mixed epochs: hub-rooted inserts (forces slab allocation), deletes
    sampled from the live ledger.  Yields (ins, dels) per epoch."""
    n_del = int(batch * delete_frac)
    n_ins = batch - n_del
    for _ in range(n_epochs):
        s = rng.integers(0, n_hubs, n_ins).astype(np.uint32)
        d = rng.integers(0, KEY_SPACE, n_ins).astype(np.uint32)
        ins = np.stack([s, d], axis=1)
        pool = np.array(sorted(ledger), np.uint32) if ledger else \
            np.zeros((0, 2), np.uint32)
        take = min(n_del, len(pool))
        dels = pool[rng.choice(len(pool), take, replace=False)] if take \
            else pool
        ledger -= {(int(a), int(b)) for a, b in dels}
        ledger |= {(int(a), int(b)) for a, b in ins}
        yield ins, dels


def run(scale: str = "quick"):
    if scale == "quick":
        V, n_hubs, E0, epochs, batch = 512, 8, 16000, 104, 4096
    else:
        V, n_hubs, E0, epochs, batch = 2048, 32, 64000, 144, 8192
    delete_frac = 0.5
    rng = np.random.default_rng(77)
    src0 = rng.integers(0, n_hubs, E0).astype(np.uint32)
    dst0 = rng.integers(0, KEY_SPACE, E0).astype(np.uint32)

    def build(policy):
        return GraphStore.from_edges(V, src0, dst0, hashing=False,
                                     with_transpose=False,
                                     with_symmetric=False,
                                     maintenance=policy)

    policy = MaintenancePolicy(tombstone_ratio=0.2)
    runs = {}
    for name, pol in (("unmaintained", None), ("maintained", policy)):
        store = build(pol)
        ledger = set(zip(src0.tolist(), dst0.tolist()))
        stream_rng = np.random.default_rng(1234)   # identical streams
        caps = [store.forward.capacity_slabs]      # plain int, no pool scan
        for ins, dels in _hub_stream(stream_rng, n_hubs=n_hubs,
                                     n_epochs=epochs, batch=batch,
                                     delete_frac=delete_frac,
                                     ledger=ledger):
            store.apply(ins_src=ins[:, 0], ins_dst=ins[:, 1],
                        del_src=dels[:, 0] if len(dels) else (),
                        del_dst=dels[:, 1] if len(dels) else ())
            caps.append(store.forward.capacity_slabs)
        runs[name] = dict(store=store, ledger=ledger, caps=caps,
                          stats=store.pool_stats())

    # --- 1. correctness: both stores match the set-oracle ledger ------------
    for name, r in runs.items():
        ledger = r["ledger"]
        pool = np.array(sorted(ledger), np.uint32)
        neg = np.stack([rng.integers(0, n_hubs, 2048),
                        rng.integers(0, KEY_SPACE, 2048)], 1).astype(
                            np.uint32)
        qs = np.concatenate([pool[:4096, 0], neg[:, 0]])
        qd = np.concatenate([pool[:4096, 1], neg[:, 1]])
        got = r["store"].query(qs, qd)
        want = np.array([(int(a), int(b)) in ledger
                         for a, b in zip(qs, qd)])
        assert np.array_equal(got, want), \
            f"{name} store diverged from the set oracle"
    assert runs["maintained"]["ledger"] == runs["unmaintained"]["ledger"]

    # --- 2. engine == oracle on the churned pool ----------------------------
    g_churned = runs["unmaintained"]["store"].forward
    g_jnp, rep = compact(g_churned, impl="jnp")
    g_orc, _ = compact(g_churned, impl="oracle")
    g_pal, _ = compact(g_churned, impl="pallas", interpret=True)
    assert _tree_equal(g_jnp, g_orc), \
        "compaction engine (jnp) != oracle rebuild"
    assert _tree_equal(g_pal, g_orc), \
        "compaction engine (pallas-interpret) != oracle rebuild"

    # --- 3. memory: maintained capacity strictly below unmaintained ---------
    cap_m = runs["maintained"]["stats"]["capacity_slabs"]
    cap_u = runs["unmaintained"]["stats"]["capacity_slabs"]
    nf_m = runs["maintained"]["stats"]["next_free"]
    nf_u = runs["unmaintained"]["stats"]["next_free"]
    st_m = runs["maintained"]["store"]
    row("churn_capacity_unmaintained", cap_u,
        f"next_free={nf_u};tombstone_ratio="
        f"{runs['unmaintained']['stats']['tombstone_ratio']:.3f}")
    row("churn_capacity_maintained", cap_m,
        f"next_free={nf_m};passes={st_m.maintenance_count};"
        f"tombstone_ratio={runs['maintained']['stats']['tombstone_ratio']:.3f}")
    assert st_m.maintenance_count > 0, "maintenance never triggered"
    assert cap_m < cap_u, \
        f"maintained capacity {cap_m} not below unmaintained {cap_u}"
    assert nf_m < nf_u, \
        f"maintained high-water {nf_m} not below unmaintained {nf_u}"
    assert max(runs["maintained"]["caps"]) <= max(
        runs["unmaintained"]["caps"]), "maintained pool peaked higher"

    # --- 4. sweep latency: compacted pool beats the tombstone-riddled one ---
    values = jnp.ones((V,), jnp.float32)
    us_churned, us_compact = _ab_times(
        lambda: sweep_vertices(g_churned, values, semiring="sum"),
        lambda: sweep_vertices(st_m.forward, values, semiring="sum"))
    row("churn_sweep_tombstoned", us_churned,
        f"capacity={g_churned.capacity_slabs}")
    row("churn_sweep_compacted", us_compact,
        f"capacity={st_m.forward.capacity_slabs};"
        f"speedup={us_churned / us_compact:.2f}x")
    assert us_compact < us_churned, \
        "post-compaction sweep not faster than the tombstone-riddled pool"

    payload = {
        "backend": jax.default_backend(),
        "scale": scale,
        "workload": {"V": V, "hubs": n_hubs, "E0": E0, "epochs": epochs,
                     "batch": batch, "delete_frac": delete_frac},
        "policy": {"tombstone_ratio": policy.tombstone_ratio},
        "note": ("identical hub-skewed churn streams; maintained = "
                 "MaintenancePolicy compaction + free-slab recycling at "
                 "epoch close (kernels/slab_compact), unmaintained = "
                 "append-only update plane.  capacity in slabs (128 lanes "
                 "x 4B each); sweep rows are sum-semiring "
                 "sweep_vertices over the forward pool."),
        "results": {
            "capacity_slabs": {"unmaintained": cap_u, "maintained": cap_m},
            "next_free": {"unmaintained": nf_u, "maintained": nf_m},
            "capacity_trajectory": {k: r["caps"] for k, r in runs.items()},
            "maintenance_passes": st_m.maintenance_count,
            "tombstone_ratio": {
                k: round(r["stats"]["tombstone_ratio"], 4)
                for k, r in runs.items()},
            "sweep_us": {"tombstoned": round(us_churned, 1),
                         "compacted": round(us_compact, 1),
                         "speedup": round(us_churned / us_compact, 3)},
            "compacted_equals_oracle": True,
        },
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("churn_bench_json", 0.0, str(_OUT.name))
