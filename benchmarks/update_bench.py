"""Old path vs slab-update engine — the paper's Fig. 5-style update plane.

Reproduces the query / insert / delete / mixed throughput sweep over batch
sizes, A/B-ing the pre-engine path (the whole-pool jnp oracle the entry
points used to be) against the fused engine, plus the GraphStore multi-view
apply per view count (legacy per-view pipeline vs the single stacked
``update_views`` dispatch).  Engine/oracle agreement is asserted on every
workload (final graphs must be leaf-for-leaf identical) — this module
doubles as the CI update-plane smoke.

Two measurement styles:

* ``*_stream`` rows — the streaming regime the engine is built for: a
  sequence of batches threads the graph through the op, the engine donating
  buffers (in-place pool mutation), the old path paying the functional
  copy.  ``mixed_stream`` (delete+insert per round, one fused dispatch) is
  the paper's update benchmark shape and the acceptance metric.
* plain rows — one stateless call on a fixed graph (no donation possible
  for either side), isolating the run-local-planning win alone.

Results append to the CSV stream and are written to ``BENCH_update.json``.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (ensure_capacity, from_edges_host, next_pow2,
                        update_slab_pointers)
from repro.core.batch import (apply_update, delete_edges, insert_edges,
                              query_edges)
from repro.core.hashing import INVALID_VERTEX
from repro.data.synth import rmat_edges
from repro.stream import GraphStore
from repro.stream.store import _pad_f32, _pad_u32

from .timing import row, time_fn

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_update.json"

_pad = _pad_u32


def _copy(g):
    return jax.tree_util.tree_map(jnp.array, g)


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _stream(g0, batches, step, iters=3):
    """Median wall-time (us) of threading ``batches`` through ``step``."""
    ts = []
    out = None
    for _ in range(iters):
        g = _copy(g0)
        jax.block_until_ready(g.keys)
        t0 = time.perf_counter()
        for b in batches:
            g = step(g, b)
        jax.block_until_ready(g.keys)
        ts.append(time.perf_counter() - t0)
        out = g
    ts.sort()
    return ts[len(ts) // 2] * 1e6, out


def _legacy_store_apply(views, weighted, ins_src, ins_dst, ins_w,
                        del_src, del_dst):
    """The PR-2 GraphStore.apply pipeline: per-phase jit calls through the
    oracle path, one functional copy per view per phase, host syncs between
    phases.  Kept here as the A/B baseline for the stacked dispatch."""
    from repro.stream.store import dedup_pairs
    i_s, i_d, i_w = dedup_pairs(ins_src, ins_dst, ins_w)
    d_s, d_d, _ = dedup_pairs(del_src, del_dst)
    if weighted and len(i_s) and i_w is None:
        i_w = np.ones(len(i_s), np.float32)
    fwd = views.get("forward")
    tr = views.get("transpose")
    sym = views.get("symmetric")
    kw = dict(impl="oracle")
    if len(i_s):
        p = next_pow2(len(i_s))
        fwd = ensure_capacity(fwd, p + 64)
        if tr is not None:
            tr = ensure_capacity(tr, p + 64)
        if sym is not None:
            sym = ensure_capacity(sym, 2 * p + 64)
    if len(d_s):
        p = next_pow2(len(d_s))
        ds, dd = _pad_u32(d_s, p), _pad_u32(d_d, p)
        fwd, dm = delete_edges(fwd, ds, dd, **kw)
        if tr is not None:
            tr, _ = delete_edges(tr, dd, ds, **kw)
        if sym is not None:
            rev = query_edges(fwd, dd, ds, **kw)
            gone = ~rev
            s2 = jnp.concatenate([jnp.where(gone, ds, INVALID_VERTEX),
                                  jnp.where(gone, dd, INVALID_VERTEX)])
            d2 = jnp.concatenate([dd, ds])
            sym, _ = delete_edges(sym, s2, d2, **kw)
        int(jnp.sum(dm.astype(jnp.int32)))          # legacy host sync
    if len(i_s):
        p = next_pow2(len(i_s))
        s, d = _pad_u32(i_s, p), _pad_u32(i_d, p)
        w = _pad_f32(i_w, p)
        fwd, im = insert_edges(fwd, s, d, w, **kw)
        if tr is not None:
            tr, _ = insert_edges(tr, d, s, w, **kw)
        if sym is not None:
            sym, _ = insert_edges(sym, jnp.concatenate([s, d]),
                                  jnp.concatenate([d, s]),
                                  None if w is None
                                  else jnp.concatenate([w, w]), **kw)
        int(jnp.sum(im.astype(jnp.int32)))          # legacy host sync
    out = {}
    for name, g in (("forward", fwd), ("transpose", tr), ("symmetric", sym)):
        if g is not None:
            out[name] = update_slab_pointers(g)
    return out


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (200000, 2000000)
    rounds = 6
    src, dst = rmat_edges(V, E, seed=21)
    E = len(src)
    rng = np.random.default_rng(42)
    g0 = from_edges_host(V, src, dst, hashing=True, slack_slabs=4096)

    results = []

    def record(name, old_us, new_us, extra=""):
        results.append({"name": name,
                        "old_us": round(old_us, 1),
                        "new_us": round(new_us, 1),
                        "speedup": round(old_us / new_us, 3) if new_us
                        else None})
        row(f"update_{name}_old", old_us)
        row(f"update_{name}_engine", new_us,
            f"speedup={old_us / new_us:.2f}x" + (f";{extra}" if extra else ""))

    for bs in (2048, 4096, 8192):
        gq = ensure_capacity(g0, rounds * bs + 64)

        # --- query (Fig. 5): random batches against the static graph ------
        qs = _pad(rng.integers(0, V, bs), bs)
        qd = _pad(rng.integers(0, V, bs), bs)
        ref = np.asarray(query_edges(gq, qs, qd, impl="oracle"))
        got = np.asarray(query_edges(gq, qs, qd))
        assert np.array_equal(ref, got), "query engine/oracle disagreement"
        old = time_fn(lambda: query_edges(gq, qs, qd, impl="oracle"))
        new = time_fn(lambda: query_edges(gq, qs, qd))
        record(f"query_b{bs}", old, new, f"Mqps={bs / new:.2f}")

        # --- streaming batch stream (same batches for every path) ---------
        ins_batches = [( _pad(rng.integers(0, V, bs), bs),
                         _pad(rng.integers(0, V, bs), bs))
                       for _ in range(rounds)]
        del_idx = [rng.choice(E, bs, replace=False) for _ in range(rounds)]
        del_batches = [(_pad(src[i], bs), _pad(dst[i], bs)) for i in del_idx]

        # insert stream: old functional path vs donated engine
        old, g_old = _stream(
            gq, ins_batches,
            lambda g, b: insert_edges(g, b[0], b[1], impl="oracle")[0])
        new, g_new = _stream(
            gq, ins_batches,
            lambda g, b: insert_edges(g, b[0], b[1], donate=True)[0])
        assert _tree_equal(g_old, g_new), "insert engine/oracle disagreement"
        record(f"insert_stream_b{bs}", old / rounds, new / rounds,
               f"Meps={bs / (new / rounds):.2f}")

        # delete stream
        old, g_old = _stream(
            gq, del_batches,
            lambda g, b: delete_edges(g, b[0], b[1], impl="oracle")[0])
        new, g_new = _stream(
            gq, del_batches,
            lambda g, b: delete_edges(g, b[0], b[1], donate=True)[0])
        assert _tree_equal(g_old, g_new), "delete engine/oracle disagreement"
        record(f"delete_stream_b{bs}", old / rounds, new / rounds,
               f"Meps={bs / (new / rounds):.2f}")

        # mixed stream — the acceptance workload: delete+insert per round;
        # old = two functional oracle dispatches, engine = one fused donated
        mixed = list(zip(del_batches, ins_batches))

        def old_step(g, b):
            g, _ = delete_edges(g, b[0][0], b[0][1], impl="oracle")
            g, _ = insert_edges(g, b[1][0], b[1][1], impl="oracle")
            return g

        def new_step(g, b):
            g, _, _ = apply_update(g, b[1][0], b[1][1], None,
                                   b[0][0], b[0][1])
            return g

        old, g_old = _stream(gq, mixed, old_step)
        new, g_new = _stream(gq, mixed, new_step)
        assert _tree_equal(g_old, g_new), "mixed engine/oracle disagreement"
        record(f"mixed_stream_b{bs}", old / rounds, new / rounds,
               f"Meps={2 * bs / (new / rounds):.2f}")

    # --- GraphStore.apply per view count ----------------------------------
    bs = 2048
    batches = [
        dict(ins_src=rng.integers(0, V, bs).astype(np.uint32),
             ins_dst=rng.integers(0, V, bs).astype(np.uint32),
             del_src=src[rng.choice(E, bs, replace=False)],
             del_dst=dst[rng.choice(E, bs, replace=False)])
        for _ in range(rounds)
    ]
    for n_views, (wt, ws) in {1: (False, False), 2: (True, False),
                              3: (True, True)}.items():
        # hashing=True is the paper's update-benchmark configuration (short
        # bucket chains); it also matches the raw-op sweep above.
        store = GraphStore.from_edges(V, src, dst, hashing=True,
                                      with_transpose=wt,
                                      with_symmetric=ws, slack_slabs=4096)
        legacy_views = {k: _copy(v) for k, v in store.views.items()}

        # warmup both paths over the FULL batch sequence on throwaway state:
        # capacity growth walks the pow2 pool ladder, and every rung's jit
        # specialisation must be out of the steady-state timing for both
        # pipelines
        warm = {k: _copy(v) for k, v in store.views.items()}
        warm_store = GraphStore.from_edges(V, src, dst, hashing=True,
                                           with_transpose=wt,
                                           with_symmetric=ws,
                                           slack_slabs=4096)
        for b in batches:
            warm = _legacy_store_apply(warm, False, ins_w=None, **b)
            warm_store.apply(ins_src=b["ins_src"], ins_dst=b["ins_dst"],
                             del_src=b["del_src"], del_dst=b["del_dst"])

        t0 = time.perf_counter()
        for b in batches:
            legacy_views = _legacy_store_apply(legacy_views, False, ins_w=None,
                                               **b)
        jax.block_until_ready(legacy_views["forward"].keys)
        legacy_us = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        for b in batches:
            store.apply(ins_src=b["ins_src"], ins_dst=b["ins_dst"],
                        del_src=b["del_src"], del_dst=b["del_dst"])
        jax.block_until_ready(store.forward.keys)
        store_us = (time.perf_counter() - t0) * 1e6

        for name, g in store.views.items():
            assert _tree_equal(g, legacy_views[name]), \
                f"store view {name} diverged from legacy pipeline"
        record(f"store_apply_views{n_views}", legacy_us / rounds,
               store_us / rounds, f"batch={bs}ins+{bs}del")

    payload = {
        "backend": jax.default_backend(),
        "scale": scale,
        "graph": {"V": V, "E": int(E)},
        "note": ("old = pre-engine whole-pool jnp path (impl='oracle', "
                 "functional copies, per-phase dispatches); engine = "
                 "kernels/slab_update (impl='auto': Pallas on TPU, "
                 "run-local jnp elsewhere; *_stream rows donate buffers "
                 "for in-place pool mutation). store_apply rows A/B the "
                 "legacy per-view pipeline against the stacked "
                 "update_views dispatch with one host-side dedup."),
        "results": results,
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("update_bench_json", 0.0, str(_OUT.name))

    mixed_rows = [r for r in results if r["name"].startswith("mixed_stream")]
    worst = min(r["speedup"] for r in mixed_rows)
    assert worst >= 2.0, \
        f"mixed-workload speedup regressed below 2x: {mixed_rows}"
