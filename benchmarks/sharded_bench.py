"""Sharded single-program plane A/B — BENCH_sharded.json.

Comparisons on an 8-virtual-device host mesh (the same
``--xla_force_host_platform_device_count=8`` rig as the multidevice test):

* ``mixed_stream`` — the legacy sharded update path (owner routing +
  per-op ``vmap(B.insert_edges)`` / ``vmap(B.delete_edges)``, functional
  pool copies, two dispatches per round) vs the engine-backed path
  (``apply_update_sharded``: one fused, donated dispatch per round).
  Final pools are asserted leaf-for-leaf identical; the engine must not
  lose.
* ``store_apply_8shard_vs_1shard`` — the acceptance row:
  ``ShardedGraphStore.apply`` under shard_map dispatch (8 shards, one
  single-program epoch: on-device all-to-all routing + every view's
  delete/insert + epoch close) vs the 1-shard ``GraphStore.apply`` on the
  same sliding-window mixed stream (each round inserts a uniform batch and
  deletes the batch inserted two rounds earlier — the classic windowed
  dynamic-graph workload; deletes are balanced across owners).  Must reach
  speedup >= 1.0; the shard_map and vmap-fallback final pools are asserted
  leaf-for-leaf identical.
* ``store_apply_..._hubdel`` — transparency row, NOT gated: the same
  stream but with deletes sampled uniformly from the rmat edge list.
  Power-law hubs concentrate deletes onto single owners, so the per-owner
  bucket-max width (the SPMD batch width every shard pays) inflates ~3-4x
  over the mean — the adversarial regime for vertex partitioning.  The row
  documents it instead of hiding it.
* ``store_scaling_S{n}`` — the acceptance stream at S in {1, 2, 4, 8}
  shard_map shards vs the same 1-shard baseline.
* ``phase_*`` — per-epoch phase breakdown of the single program at S=8,
  via standalone probe programs: collective exchange alone, routing
  (sort + exchange + compaction), engine dispatch (full program minus
  routing), and host overhead (wall clock minus device program).
* ``sweep_*`` — distributed analytics super-step throughput under
  shard_map dispatch vs the single-graph engines on the unsharded union.
  Must reach speedup >= 1.0; WCC labels are asserted bit-identical across
  1-shard/vmap/shard_map, PageRank bit-identical between dispatch modes
  (vs 1-shard: allclose — the per-shard sweep regroups the f32 sums).

XLA locks the device count at first init, so ``run()`` re-execs this module
in a subprocess with the forced-device env (benchmarks.run stays usable
in-process).  Absolute times on a host-platform mesh are NOT a model of TPU
all-to-all cost — the 8 virtual devices serialize on the host cores, so
every ratio here is a lower bound on real-mesh scaling: the ratios track
engine-vs-legacy work, not the wire.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sharded.json"


def run(scale: str = "quick"):
    """benchmarks.run entry point: re-exec with the 8-device env."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_bench", "--scale", scale],
        env=env, cwd=pathlib.Path(__file__).resolve().parent.parent)
    if out.returncode != 0:
        raise RuntimeError(f"sharded_bench subprocess failed "
                           f"(rc={out.returncode})")


def _main(scale: str):
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import dataclasses

    from repro.algorithms import pagerank, wcc_labelprop_sweep
    from repro.core import batch as B
    from repro.core import from_edges_host
    from repro.data.synth import rmat_edges
    from repro.distributed.collectives import exchange_buckets
    from repro.distributed.sharded_graph import (SHARD_AXIS,
                                                 apply_update_sharded,
                                                 ensure_capacity_sharded,
                                                 max_owner_count,
                                                 pagerank_sharded,
                                                 place_on_mesh,
                                                 route_edges, route_exchange,
                                                 routing_cap_blocks,
                                                 shard_from_edges_host,
                                                 wcc_sharded)
    from repro.stream import GraphStore, ShardedGraphStore
    from repro.stream.sharded_store import _cap_rung

    from .timing import row

    S = min(8, len(jax.devices()))
    # streams run at a bulk-update scale (the regime the single-program
    # plane is for); "full" additionally grows the graph
    V, E, bs, rounds = ((1 << 15, 240000, 8192, 3) if scale == "quick"
                        else (1 << 17, 1000000, 8192, 4))
    lag = 2          # sliding window: round t deletes the round t-lag batch
    rng = np.random.default_rng(33)
    src, dst = rmat_edges(V, E, seed=33)
    E = len(src)

    mesh = jax.make_mesh((S,), (SHARD_AXIS,))

    def copy_sg(sg):
        return dataclasses.replace(
            sg, graphs=jax.tree.map(jnp.array, sg.graphs))

    def tree_equal(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    def median(ts):
        ts = sorted(ts)
        return ts[len(ts) // 2]

    results = []

    def record(name, old_us, new_us, extra=""):
        results.append({"name": name, "old_us": round(old_us, 1),
                        "new_us": round(new_us, 1),
                        "speedup": round(old_us / new_us, 3)})
        row(f"sharded_{name}_old", old_us)
        row(f"sharded_{name}_new", new_us,
            f"speedup={old_us / new_us:.2f}x" + (f";{extra}" if extra else ""))

    # -- workloads ----------------------------------------------------------
    # sliding-window stream: uniform inserts, deletes = the batch inserted
    # `lag` rounds earlier (balanced per-owner delete counts)
    uni = [(rng.integers(0, V, bs).astype(np.uint32),
            rng.integers(0, V, bs).astype(np.uint32))
           for _ in range(rounds + lag)]
    window_warm = [dict(ins_src=u[0], ins_dst=u[1]) for u in uni[:lag]]
    window_batches = [dict(ins_src=uni[t + lag][0], ins_dst=uni[t + lag][1],
                           del_src=uni[t][0], del_dst=uni[t][1])
                      for t in range(rounds)]
    # hub-skewed stream: deletes sampled from the rmat edge list
    del_idx = [rng.choice(E, bs, replace=False) for _ in range(rounds)]
    hub_batches = [dict(ins_src=uni[t + lag][0], ins_dst=uni[t + lag][1],
                        del_src=src[del_idx[t]], del_dst=dst[del_idx[t]])
                   for t in range(rounds)]

    # -- mixed update stream: legacy vmap-per-op vs fused donated engine ----
    sg0 = ensure_capacity_sharded(shard_from_edges_host(V, S, src, dst),
                                  (rounds + 1) * bs + 64)
    stream_pairs = [((jnp.asarray(b["del_src"]), jnp.asarray(b["del_dst"])),
                     (jnp.asarray(b["ins_src"]), jnp.asarray(b["ins_dst"])))
                    for b in hub_batches]

    def legacy_step(sg, dels, ins):
        # the pre-engine path: route + one vmapped engine entry per op,
        # no donation (a functional copy of every shard pool per op)
        ds, dd, _, _, _ = route_edges(dels[0], dels[1], n_shards=S, cap=bs)
        graphs, _ = jax.vmap(B.delete_edges)(sg.graphs, ds, dd)
        sg = dataclasses.replace(sg, graphs=graphs)
        bsrc, bdst, _, _, _ = route_edges(ins[0], ins[1], n_shards=S, cap=bs)
        graphs, _ = jax.vmap(B.insert_edges)(sg.graphs, bsrc, bdst)
        return dataclasses.replace(sg, graphs=graphs)

    def engine_step(sg, dels, ins):
        sg, _, _ = apply_update_sharded(sg, ins[0], ins[1], None,
                                        dels[0], dels[1], cap=bs,
                                        donate=True)
        return sg

    def stream(step, iters=3):
        ts, out = [], None
        for _ in range(iters):
            sg = copy_sg(sg0)
            jax.block_until_ready(sg.graphs.keys)
            t0 = time.perf_counter()
            for dels, ins in stream_pairs:
                sg = step(sg, dels, ins)
            jax.block_until_ready(sg.graphs.keys)
            ts.append(time.perf_counter() - t0)
            out = sg
        return median(ts) * 1e6, out

    old_us, g_old = stream(legacy_step)
    new_us, g_new = stream(engine_step)
    assert tree_equal(g_old.graphs, g_new.graphs), \
        "sharded engine/legacy pool disagreement"
    record(f"mixed_stream_b{bs}", old_us / rounds, new_us / rounds,
           f"Meps={2 * bs / (new_us / rounds):.2f}")
    assert new_us <= old_us, \
        f"engine-backed sharded apply lost to legacy: {new_us} vs {old_us}"

    # -- store apply: shard_map single-program epochs vs 1-shard store ------
    def store_stream(make, batches, iters=3):
        st = make()          # compile pass on throwaway state
        for b in window_warm + batches:
            st.apply(**b)
        ts = []
        for _ in range(iters):
            st = make()
            for b in window_warm:
                st.apply(**b)
            jax.block_until_ready(jax.tree.leaves(st.forward)[0])
            t0 = time.perf_counter()
            for b in batches:
                st.apply(**b)
            jax.block_until_ready(jax.tree.leaves(st.forward)[0])
            ts.append(time.perf_counter() - t0)
        return median(ts) * 1e6, st

    def make_one():
        return GraphStore.from_edges(
            V, src, dst, hashing=False,
            slack_slabs=(rounds + lag + 1) * bs // 16)

    def make_sharded(n_shards=S, dispatch="auto"):
        def make():
            st = ShardedGraphStore.from_edges(V, n_shards, src, dst,
                                              dispatch=dispatch)
            if dispatch != "vmap":
                st.place_on_mesh(
                    jax.make_mesh((n_shards,), (SHARD_AXIS,),
                                  devices=jax.devices()[:n_shards]))
            return st
        return make

    one_us, _ = store_stream(make_one, window_batches)
    sm_us, st_sm = store_stream(make_sharded(), window_batches)
    sv_us, st_sv = store_stream(make_sharded(dispatch="vmap"),
                                window_batches)
    assert tree_equal(tuple(st_sm.views[r].graphs for r in st_sm.views),
                      tuple(st_sv.views[r].graphs for r in st_sv.views)), \
        "shard_map/vmap final pools disagree"
    record("store_apply_8shard_vs_1shard", one_us / rounds, sm_us / rounds,
           f"batch={bs}ins+{bs}del;window;recompiles={st_sm.recompile_count}")
    record("store_apply_8shard_vs_1shard_vmap_fallback",
           one_us / rounds, sv_us / rounds,
           f"window;recompiles={st_sv.recompile_count}")

    one_hub_us, _ = store_stream(make_one, hub_batches)
    hub_us, st_hub = store_stream(make_sharded(), hub_batches)
    record("store_apply_8shard_vs_1shard_hubdel",
           one_hub_us / rounds, hub_us / rounds,
           "rmat-sampled deletes: per-owner bucket-max width inflates "
           "~3-4x under hub skew")

    # -- shard scaling on the acceptance stream -----------------------------
    for n_shards in (1, 2, 4, 8):
        if n_shards > S:
            continue
        if n_shards == S:
            s_us = sm_us     # same config as the acceptance row — reuse
        else:
            s_us, _ = store_stream(make_sharded(n_shards), window_batches)
        record(f"store_scaling_S{n_shards}", one_us / rounds, s_us / rounds,
               "window;single-program shard_map")

    # -- phase breakdown of the single-program epoch at S=8 -----------------
    # standalone probes at the acceptance-stream caps; engine time is the
    # full-program residual over routing, host overhead the wall-clock
    # residual over the device program
    d_s, d_d = window_batches[0]["del_src"], window_batches[0]["del_dst"]
    i_s, i_d = window_batches[0]["ins_src"], window_batches[0]["ins_dst"]
    caps = {}
    for slot, arr in (("del_s", d_s), ("del_d", d_d),
                      ("ins_s", i_s), ("ins_d", i_d)):
        caps[slot] = (routing_cap_blocks(arr, S, bs // S),
                      _cap_rung(max_owner_count(arr, S)))
    probe_args = tuple(jnp.asarray(a) for a in (d_s, d_d, i_s, i_d))
    vec = P(SHARD_AXIS)

    def route_probe(ds_l, dd_l, is_l, id_l):
        outs = []
        for s, d, cap in ((ds_l, dd_l, caps["del_s"]),
                          (dd_l, ds_l, caps["del_d"]),
                          (is_l, id_l, caps["ins_s"]),
                          (id_l, is_l, caps["ins_d"])):
            bs_, bd_, _, orig, _ = route_exchange(s, d, None, n_shards=S,
                                                  cap=cap[0])
            perm = jnp.argsort(orig < 0, stable=True)[:cap[1]]
            outs.append(bs_[perm] ^ bd_[perm])
        return jnp.concatenate(outs)[None]

    def exchange_probe(ds_l, dd_l, is_l, id_l):
        outs = []
        for s, cap in ((ds_l, caps["del_s"]), (dd_l, caps["del_d"]),
                       (is_l, caps["ins_s"]), (id_l, caps["ins_d"])):
            blk = jnp.resize(s, (S, cap[0]))
            outs.append(exchange_buckets(blk, SHARD_AXIS).reshape(-1))
        return jnp.concatenate(outs)[None]

    def probe_time(fn, n=10):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=(vec,) * 4,
                              out_specs=P(SHARD_AXIS, None),
                              check_rep=False))
        jax.block_until_ready(f(*probe_args))
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(*probe_args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e6

    t_exchange = probe_time(exchange_probe)
    t_route = probe_time(route_probe)
    epoch_us = sm_us / rounds
    # device program time: one donated single-program epoch re-dispatched
    # on the final store state (compiled path, median of repeats)
    st_p = make_sharded()()
    for b in window_warm + window_batches:
        st_p.apply(**b)
    ts = []
    for t in range(5):
        b = window_batches[t % rounds]
        jax.block_until_ready(jax.tree.leaves(st_p.forward)[0])
        t0 = time.perf_counter()
        st_p.apply(**b)
        jax.block_until_ready(jax.tree.leaves(st_p.forward)[0])
        ts.append(time.perf_counter() - t0)
    t_program = median(ts) * 1e6
    phases = {
        "exchange_us": round(t_exchange, 1),
        "route_us": round(max(t_route - t_exchange, 0.0), 1),
        "engine_dispatch_us": round(max(t_program - t_route, 0.0), 1),
        "host_overhead_us": round(max(epoch_us - t_program, 0.0), 1),
    }
    for k, v in phases.items():
        row(f"sharded_phase_{k}", v)

    # -- sweep throughput: distributed analytics vs unsharded union ---------
    def sweep_time(fn, iters, n=3):
        jax.block_until_ready(fn())
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return median(ts) * 1e6 / iters

    g_in = from_edges_host(V, dst, src, hashing=False)
    sg_in_v = shard_from_edges_host(V, S, dst, src)
    sg_in_m = place_on_mesh(copy_sg(sg_in_v), mesh)
    out_deg = jnp.asarray(from_edges_host(V, src, dst,
                                          hashing=False).degree)

    iters = 20
    pr_one = pagerank(g_in, out_deg, max_iter=iters, error_margin=0.0)[0]
    pr_v = pagerank_sharded(sg_in_v, out_deg, max_iter=iters,
                            error_margin=0.0)[0]
    pr_m = pagerank_sharded(sg_in_m, out_deg, max_iter=iters,
                            error_margin=0.0)[0]
    assert np.array_equal(np.asarray(pr_v), np.asarray(pr_m)), \
        "pagerank dispatch modes disagree bitwise"
    np.testing.assert_allclose(np.asarray(pr_m), np.asarray(pr_one),
                               atol=1e-5)
    t_old = sweep_time(lambda: pagerank(g_in, out_deg, max_iter=iters,
                                        error_margin=0.0)[0], iters)
    t_new = sweep_time(lambda: pagerank_sharded(sg_in_m, out_deg,
                                                max_iter=iters,
                                                error_margin=0.0)[0], iters)
    record("sweep_pagerank", t_old, t_new, f"us_per_superstep;S={S}")

    # wcc sweeps over the symmetric union (labels bit-identical — asserted)
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    g_sym = from_edges_host(V, s2, d2, hashing=False)
    sg_sym_v = shard_from_edges_host(V, S, s2, d2)
    sg_sym_m = place_on_mesh(copy_sg(sg_sym_v), mesh)
    lab_old, it_old = wcc_labelprop_sweep(g_sym)
    lab_v, _ = wcc_sharded(sg_sym_v)
    lab_m, it_new = wcc_sharded(sg_sym_m)
    assert np.array_equal(np.asarray(lab_old), np.asarray(lab_m))
    assert np.array_equal(np.asarray(lab_v), np.asarray(lab_m))
    t_old = sweep_time(lambda: wcc_labelprop_sweep(g_sym)[0], int(it_old))
    t_new = sweep_time(lambda: wcc_sharded(sg_sym_m)[0], int(it_new))
    record("sweep_wcc", t_old, t_new, f"us_per_superstep;S={S}")

    # -- acceptance gates ---------------------------------------------------
    gated = {"store_apply_8shard_vs_1shard", "sweep_pagerank", "sweep_wcc"}
    for r in results:
        if r["name"] in gated:
            assert r["speedup"] >= 1.0, \
                f"{r['name']} below parity: {r['speedup']}x"

    payload = {
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "scale": scale,
        "graph": {"V": V, "E": int(E), "shards": S,
                  "batch": bs, "rounds": rounds},
        "phases": phases,
        "note": ("host-platform 8-device mesh (devices serialize on the "
                 "host cores — ratios are a lower bound on real-mesh "
                 "scaling); old = legacy sharded path / 1-shard store / "
                 "unsharded analytics; new = single-program shard_map "
                 "plane (one donated epoch program: all-to-all routing + "
                 "every view's delete/insert + epoch close; collective "
                 "exchange sweeps).  store_apply rows use the "
                 "sliding-window stream; the _hubdel row keeps the "
                 "skew-adversarial rmat-delete workload visible."),
        "results": results,
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("sharded_bench_json", 0.0, str(_OUT.name))


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick")
    _main(ap.parse_args().scale)
