"""Sharded stream plane A/B — BENCH_sharded.json.

Three comparisons on an 8-virtual-device host mesh (the same
``--xla_force_host_platform_device_count=8`` rig as the multidevice test):

* ``sharded_mixed_stream`` — the acceptance row: the legacy sharded update
  path (owner routing + per-op ``vmap(B.insert_edges)`` / ``vmap(
  B.delete_edges)``, functional pool copies, two dispatches per round)
  vs the engine-backed path (``apply_update_sharded``: one fused, donated
  ``update_shards`` dispatch per round).  Final pools are asserted
  leaf-for-leaf identical; the engine must not lose.
* ``store_apply`` — ``ShardedGraphStore.apply`` (8 shards) vs the 1-shard
  ``GraphStore.apply`` on the same mixed stream: the cost of the sharded
  plane's routing exchange vs the unsharded multi-view apply.
* ``sweep_*`` — distributed analytics super-step throughput:
  ``pagerank_sharded`` / ``wcc_sharded`` vs the single-graph engines on the
  unsharded union.

XLA locks the device count at first init, so ``run()`` re-execs this module
in a subprocess with the forced-device env (benchmarks.run stays usable
in-process).  Absolute times on a host-platform mesh are NOT a model of TPU
all-to-all cost — the ratios track engine-vs-legacy work, not the wire.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sharded.json"


def run(scale: str = "quick"):
    """benchmarks.run entry point: re-exec with the 8-device env."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_bench", "--scale", scale],
        env=env, cwd=pathlib.Path(__file__).resolve().parent.parent)
    if out.returncode != 0:
        raise RuntimeError(f"sharded_bench subprocess failed "
                           f"(rc={out.returncode})")


def _main(scale: str):
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import dataclasses

    from repro.algorithms import pagerank, wcc_labelprop_sweep
    from repro.core import batch as B
    from repro.core import from_edges_host
    from repro.data.synth import rmat_edges
    from repro.distributed.sharded_graph import (apply_update_sharded,
                                                 ensure_capacity_sharded,
                                                 pagerank_sharded,
                                                 route_edges, wcc_sharded)
    from repro.stream import GraphStore, ShardedGraphStore

    from .timing import row

    S = min(8, len(jax.devices()))
    V, E, bs, rounds = ((1 << 13, 60000, 2048, 4) if scale == "quick"
                        else (1 << 17, 1000000, 8192, 6))
    rng = np.random.default_rng(33)
    src, dst = rmat_edges(V, E, seed=33)
    E = len(src)

    mesh = jax.make_mesh((S,), ("shard",))

    def place_sg(sg):
        def place(x):
            if x.ndim == 0:
                return x
            return jax.device_put(x, NamedSharding(
                mesh, P(*(("shard",) + (None,) * (x.ndim - 1)))))
        return dataclasses.replace(sg, graphs=jax.tree.map(place, sg.graphs))

    def copy_sg(sg):
        return dataclasses.replace(
            sg, graphs=jax.tree.map(jnp.array, sg.graphs))

    def tree_equal(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    results = []

    def record(name, old_us, new_us, extra=""):
        results.append({"name": name, "old_us": round(old_us, 1),
                        "new_us": round(new_us, 1),
                        "speedup": round(old_us / new_us, 3)})
        row(f"sharded_{name}_old", old_us)
        row(f"sharded_{name}_new", new_us,
            f"speedup={old_us / new_us:.2f}x" + (f";{extra}" if extra else ""))

    # -- mixed update stream: legacy vmap-per-op vs fused donated engine ----
    ins_batches = [(jnp.asarray(rng.integers(0, V, bs).astype(np.uint32)),
                    jnp.asarray(rng.integers(0, V, bs).astype(np.uint32)))
                   for _ in range(rounds)]
    del_idx = [rng.choice(E, bs, replace=False) for _ in range(rounds)]
    del_batches = [(jnp.asarray(src[i]), jnp.asarray(dst[i]))
                   for i in del_idx]

    from repro.distributed.sharded_graph import shard_from_edges_host

    def build_sharded(s_arr, d_arr, slack):
        # compact host bulk build (dense pools), then reserve the engine's
        # worst-case per-lane slab headroom for the update stream
        sg = shard_from_edges_host(V, S, s_arr, d_arr)
        return place_sg(ensure_capacity_sharded(sg, slack))

    sg0 = build_sharded(src, dst, (rounds + 1) * bs + 64)

    def legacy_step(sg, dels, ins):
        # the pre-engine path: route + one vmapped engine entry per op,
        # no donation (a functional copy of every shard pool per op)
        ds, dd, _, _, _ = route_edges(dels[0], dels[1], n_shards=S, cap=bs)
        graphs, _ = jax.vmap(B.delete_edges)(sg.graphs, ds, dd)
        sg = dataclasses.replace(sg, graphs=graphs)
        bsrc, bdst, _, _, _ = route_edges(ins[0], ins[1], n_shards=S, cap=bs)
        graphs, _ = jax.vmap(B.insert_edges)(sg.graphs, bsrc, bdst)
        return dataclasses.replace(sg, graphs=graphs)

    def engine_step(sg, dels, ins):
        sg, _, _ = apply_update_sharded(sg, ins[0], ins[1], None,
                                        dels[0], dels[1], cap=bs,
                                        donate=True)
        return sg

    def stream(step, iters=3):
        ts, out = [], None
        for _ in range(iters):
            sg = copy_sg(sg0)
            jax.block_until_ready(sg.graphs.keys)
            t0 = time.perf_counter()
            for dels, ins in zip(del_batches, ins_batches):
                sg = step(sg, dels, ins)
            jax.block_until_ready(sg.graphs.keys)
            ts.append(time.perf_counter() - t0)
            out = sg
        ts.sort()
        return ts[len(ts) // 2] * 1e6, out

    old_us, g_old = stream(legacy_step)
    new_us, g_new = stream(engine_step)
    assert tree_equal(g_old.graphs, g_new.graphs), \
        "sharded engine/legacy pool disagreement"
    record(f"mixed_stream_b{bs}", old_us / rounds, new_us / rounds,
           f"Meps={2 * bs / (new_us / rounds):.2f}")
    assert new_us <= old_us, \
        f"engine-backed sharded apply lost to legacy: {new_us} vs {old_us}"

    # -- store apply: 8-shard sharded store vs 1-shard GraphStore -----------
    batches = [dict(ins_src=np.asarray(i[0]), ins_dst=np.asarray(i[1]),
                    del_src=np.asarray(d[0]), del_dst=np.asarray(d[1]))
               for i, d in zip(ins_batches, del_batches)]

    def store_stream(make):
        st = make()      # warmup pass on throwaway state
        for b in batches:
            st.apply(**b)
        st = make()
        t0 = time.perf_counter()
        for b in batches:
            st.apply(**b)
        jax.block_until_ready(
            st.forward.graphs.keys if hasattr(st.forward, "graphs")
            else st.forward.keys)
        return (time.perf_counter() - t0) * 1e6

    def make_sharded():
        st = ShardedGraphStore.from_edges(V, S, src, dst)
        for name, view in st.views.items():
            st._views[name] = place_sg(view)
        return st

    one_us = store_stream(lambda: GraphStore.from_edges(
        V, src, dst, hashing=False, slack_slabs=(rounds + 1) * bs // 16))
    sh_us = store_stream(make_sharded)
    record("store_apply_8shard_vs_1shard", one_us / rounds, sh_us / rounds,
           f"batch={bs}ins+{bs}del")

    # -- sweep throughput: distributed analytics vs unsharded union ---------
    g_in = from_edges_host(V, dst, src, hashing=False)
    sg_in = build_sharded(dst, src, bs + 64)
    out_deg = from_edges_host(V, src, dst, hashing=False).degree

    iters = 20
    for name, fn_old, fn_new in (
        ("pagerank",
         lambda: pagerank(g_in, out_deg, max_iter=iters,
                          error_margin=0.0)[0],
         lambda: pagerank_sharded(sg_in, out_deg, max_iter=iters,
                                  error_margin=0.0)[0]),
    ):
        jax.block_until_ready(fn_old())
        jax.block_until_ready(fn_new())
        t0 = time.perf_counter()
        jax.block_until_ready(fn_old())
        t_old = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        jax.block_until_ready(fn_new())
        t_new = (time.perf_counter() - t0) * 1e6
        record(f"sweep_{name}", t_old / iters, t_new / iters,
               f"us_per_superstep;S={S}")

    # wcc sweeps over the symmetric union (iteration counts are identical,
    # labels bit-identical — asserted)
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    g_sym = from_edges_host(V, s2, d2, hashing=False)
    sg_sym = build_sharded(s2, d2, bs + 64)
    lab_old, it_old = wcc_labelprop_sweep(g_sym)
    lab_new, it_new = wcc_sharded(sg_sym)
    assert np.array_equal(np.asarray(lab_old), np.asarray(lab_new))
    jax.block_until_ready(wcc_labelprop_sweep(g_sym)[0])
    t0 = time.perf_counter()
    jax.block_until_ready(wcc_labelprop_sweep(g_sym)[0])
    t_old = (time.perf_counter() - t0) * 1e6
    jax.block_until_ready(wcc_sharded(sg_sym)[0])
    t0 = time.perf_counter()
    jax.block_until_ready(wcc_sharded(sg_sym)[0])
    t_new = (time.perf_counter() - t0) * 1e6
    record("sweep_wcc", t_old / int(it_old), t_new / int(it_new),
           f"us_per_superstep;S={S}")

    payload = {
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "scale": scale,
        "graph": {"V": V, "E": int(E), "shards": S},
        "note": ("host-platform 8-device mesh; old = legacy sharded path "
                 "(route + per-op vmap(B.insert/delete_edges), functional "
                 "pool copies) or the 1-shard store / unsharded analytics; "
                 "new = engine-backed sharded plane (fused donated "
                 "update_shards dispatch; slab-sweep super-steps).  Ratios "
                 "track compute, not TPU interconnect."),
        "results": results,
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("sharded_bench_json", 0.0, str(_OUT.name))


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick")
    _main(ap.parse_args().scale)
