"""Paper Fig. 11 + §6.3 — triangle counting: hashing on/off ablation for the
static count, dynamic inc/dec vs full static recount."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.algorithms import (triangles_decremental, triangles_incremental,
                              triangles_static)
from repro.core import delete_edges, ensure_capacity, from_edges_host, \
    insert_edges
from repro.data.synth import rmat_edges

from .timing import row, time_fn


def pad(a, n):
    out = np.full(n, 0xFFFFFFFF, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def und(src, dst):
    pairs = {(min(int(u), int(v)), max(int(u), int(v)))
             for u, v in zip(src, dst) if u != v}
    s = np.array([p[0] for p in pairs] + [p[1] for p in pairs], np.uint32)
    d = np.array([p[1] for p in pairs] + [p[0] for p in pairs], np.uint32)
    return s, d, pairs


def run(scale: str = "quick"):
    V, E = (2000, 16000) if scale == "quick" else (10000, 120000)
    src0, dst0 = rmat_edges(V, E, seed=8)
    s, d, pairs = und(src0, dst0)

    g_hash = from_edges_host(V, s, d, hashing=True, slack_slabs=1024)
    g_flat = from_edges_host(V, s, d, hashing=False, slack_slabs=1024)
    mb = int(np.max(np.asarray(g_hash.bucket_count)))

    us_h = time_fn(lambda: triangles_static(g_hash, max_bpv=mb), iters=2)
    us_f = time_fn(lambda: triangles_static(g_flat, max_bpv=1), iters=2)
    t = int(triangles_static(g_hash, max_bpv=mb))
    row("tc_static_hash", us_h, f"triangles={t}")
    row("tc_static_nohash", us_f,
        f"hashing_speedup={us_f / us_h:.2f}x")  # paper: hashing WINS for TC

    # dynamic: one incremental batch vs recount
    rng = np.random.default_rng(9)
    batch = []
    while len(batch) < 256:
        u, v = rng.integers(0, V, 2)
        u, v = int(min(u, v)), int(max(u, v))
        if u != v and (u, v) not in pairs and (u, v) not in batch:
            batch.append((u, v))
    bs = np.array([p[0] for p in batch], np.uint32)
    bd = np.array([p[1] for p in batch], np.uint32)
    B = len(batch)
    g2 = ensure_capacity(g_hash, 2 * B + 64)
    g2, _ = insert_edges(g2, pad(np.concatenate([bs, bd]), 2 * B),
                         pad(np.concatenate([bd, bs]), 2 * B))
    g_b = from_edges_host(V, np.concatenate([bs, bd]),
                          np.concatenate([bd, bs]), hashing=True)
    mb2 = max(mb, int(np.max(np.asarray(g_b.bucket_count))))
    mask = jnp.ones(B, bool)
    us_inc = time_fn(lambda: triangles_incremental(
        g2, g_b, pad(bs, B), pad(bd, B), mask, max_bpv=mb2), iters=2)
    us_full = time_fn(lambda: triangles_static(g2, max_bpv=mb2), iters=2)
    row("tc_incremental_b256", us_inc,
        f"speedup_vs_recount={us_full / us_inc:.2f}x")

    # decremental
    dels = list(pairs)[::max(1, len(pairs) // 256)][:256]
    ds = np.array([p[0] for p in dels], np.uint32)
    dd = np.array([p[1] for p in dels], np.uint32)
    Bd = len(dels)
    g3, _ = delete_edges(g_hash, pad(np.concatenate([ds, dd]), 2 * Bd),
                         pad(np.concatenate([dd, ds]), 2 * Bd))
    g_bd = from_edges_host(V, np.concatenate([ds, dd]),
                           np.concatenate([dd, ds]), hashing=True)
    mb3 = max(mb, int(np.max(np.asarray(g_bd.bucket_count))))
    maskd = jnp.ones(Bd, bool)
    us_dec = time_fn(lambda: triangles_decremental(
        g3, g_bd, pad(ds, Bd), pad(dd, Bd), maskd, max_bpv=mb3), iters=2)
    us_full2 = time_fn(lambda: triangles_static(g3, max_bpv=mb3), iters=2)
    row("tc_decremental_b256", us_dec,
        f"speedup_vs_recount={us_full2 / us_dec:.2f}x")
