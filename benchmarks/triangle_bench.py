"""Paper Fig. 11 + §6.3 — triangle counting: hashing on/off ablation for the
static count, dynamic inc/dec vs full static recount.

Asserted (the ISSUE-9 acceptance criteria, also covered in
tests/test_triangle_stream.py):

1. every ``count_edges`` engine (pallas-interpret / jnp / oracle) returns
   the identical static count;
2. the incremental and decremental deltas land on the same totals a full
   static recount produces.

Results land in ``BENCH_triangle.json`` (and the CSV stream).
"""
from __future__ import annotations

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.algorithms import (triangles_decremental, triangles_incremental,
                              triangles_static, undirected_host)
from repro.algorithms.triangle import batch_graph
from repro.core import delete_edges, ensure_capacity, from_edges_host, \
    insert_edges
from repro.data.synth import rmat_edges
from repro.kernels.slab_intersect import count_edges

from .timing import row, time_fn

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_triangle.json"


def pad(a, n):
    out = np.full(n, 0xFFFFFFFF, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def und(src, dst):
    """Both orientations of the deduped loop-free undirected edge set —
    sort/unique on the device-free host path (no Python pair loops)."""
    lo, hi = undirected_host(src, dst)
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    s = np.concatenate([lo, hi])
    d = np.concatenate([hi, lo])
    return s, d, lo, hi


def run(scale: str = "quick"):
    V, E = (2000, 16000) if scale == "quick" else (10000, 120000)
    src0, dst0 = rmat_edges(V, E, seed=8)
    s, d, lo, hi = und(src0, dst0)
    n_und = len(lo)

    g_hash = from_edges_host(V, s, d, hashing=True, slack_slabs=1024)
    g_flat = from_edges_host(V, s, d, hashing=False, slack_slabs=1024)
    mb = int(np.max(np.asarray(g_hash.bucket_count)))

    us_h = time_fn(lambda: triangles_static(g_hash, max_bpv=mb), iters=2)
    us_f = time_fn(lambda: triangles_static(g_flat, max_bpv=1), iters=2)
    t = int(triangles_static(g_hash, max_bpv=mb))
    row("tc_static_hash", us_h, f"triangles={t}")
    row("tc_static_nohash", us_f,
        f"hashing_speedup={us_f / us_h:.2f}x")  # paper: hashing WINS for TC

    # engine ablation: every impl of the intersect family, identical count
    es, ed = jnp.asarray(s), jnp.asarray(d)
    emask = jnp.ones(len(s), bool)
    engine_counts, engine_us = {}, {}
    for impl in ("pallas", "jnp", "oracle"):
        engine_counts[impl] = int(count_edges(
            g_hash, g_hash, es, ed, emask, impl=impl, max_bpv=mb)) // 6
        engine_us[impl] = time_fn(lambda i=impl: count_edges(
            g_hash, g_hash, es, ed, emask, impl=i, max_bpv=mb), iters=2)
        row(f"tc_engine_{impl}", engine_us[impl],
            f"triangles={engine_counts[impl]}")
    assert len(set(engine_counts.values())) == 1, \
        f"count_edges engines disagree: {engine_counts}"
    assert engine_counts["oracle"] == t, \
        f"engine count {engine_counts['oracle']} != static {t}"

    # dynamic: one incremental batch vs recount.  Vectorized batch draw:
    # oversample random canonical pairs, drop loops + already-present pairs.
    rng = np.random.default_rng(9)
    cand = rng.integers(0, V, (4096, 2)).astype(np.uint32)
    clo, chi = undirected_host(cand[:, 0], cand[:, 1])
    key = clo.astype(np.uint64) << np.uint64(32) | chi.astype(np.uint64)
    present = lo.astype(np.uint64) << np.uint64(32) | hi.astype(np.uint64)
    keep = (clo != chi) & ~np.isin(key, present)
    bs, bd = clo[keep][:256], chi[keep][:256]
    B = len(bs)
    g2 = ensure_capacity(g_hash, 2 * B + 64)
    g2, _ = insert_edges(g2, pad(np.concatenate([bs, bd]), 2 * B),
                         pad(np.concatenate([bd, bs]), 2 * B))
    mask = jnp.ones(B, bool)
    g_b = batch_graph(V, jnp.asarray(bs), jnp.asarray(bd), mask)
    us_inc = time_fn(lambda: triangles_incremental(
        g2, g_b, pad(bs, B), pad(bd, B), mask, max_bpv=mb, batch_bpv=1),
        iters=2)
    us_full = time_fn(lambda: triangles_static(g2, max_bpv=mb), iters=2)
    t_inc = t + int(triangles_incremental(
        g2, g_b, pad(bs, B), pad(bd, B), mask, max_bpv=mb, batch_bpv=1))
    t_post_ins = int(triangles_static(g2, max_bpv=mb))
    assert t_inc == t_post_ins, \
        f"incremental delta {t_inc} != static recount {t_post_ins}"
    row("tc_incremental_b256", us_inc,
        f"speedup_vs_recount={us_full / us_inc:.2f}x")

    # decremental
    step = max(1, n_und // 256)
    ds, dd = lo[::step][:256], hi[::step][:256]
    Bd = len(ds)
    g3, _ = delete_edges(g_hash, pad(np.concatenate([ds, dd]), 2 * Bd),
                         pad(np.concatenate([dd, ds]), 2 * Bd))
    maskd = jnp.ones(Bd, bool)
    g_bd = batch_graph(V, jnp.asarray(ds), jnp.asarray(dd), maskd)
    us_dec = time_fn(lambda: triangles_decremental(
        g3, g_bd, pad(ds, Bd), pad(dd, Bd), maskd, max_bpv=mb, batch_bpv=1),
        iters=2)
    us_full2 = time_fn(lambda: triangles_static(g3, max_bpv=mb), iters=2)
    t_dec = t - int(triangles_decremental(
        g3, g_bd, pad(ds, Bd), pad(dd, Bd), maskd, max_bpv=mb, batch_bpv=1))
    t_post_del = int(triangles_static(g3, max_bpv=mb))
    assert t_dec == t_post_del, \
        f"decremental delta {t_dec} != static recount {t_post_del}"
    row("tc_decremental_b256", us_dec,
        f"speedup_vs_recount={us_full2 / us_dec:.2f}x")

    payload = {
        "backend": jax.default_backend(),
        "scale": scale,
        "workload": {"V": V, "E_directed": E, "E_und": n_und,
                     "batch": B, "max_bpv": mb},
        "note": ("static = slab_intersect count over the symmetric graph "
                 "(6T); dynamic = inc/dec delta formulas (paper §6.3) over "
                 "a device-built single-bucket batch graph vs a full "
                 "static recount of the post-update graph.  hashing stays "
                 "ON for TC (per-bucket chains shrink the intersect "
                 "walk); engines asserted count-identical."),
        "results": {
            "triangles": t,
            "static_us": {"hash": round(us_h, 1), "nohash": round(us_f, 1),
                          "hashing_speedup": round(us_f / us_h, 3)},
            "engine_us": {k: round(v, 1) for k, v in engine_us.items()},
            "engines_agree": True,
            "incremental": {
                "batch": B, "us": round(us_inc, 1),
                "recount_us": round(us_full, 1),
                "speedup_vs_recount": round(us_full / us_inc, 3),
                "delta_matches_recount": True},
            "decremental": {
                "batch": Bd, "us": round(us_dec, 1),
                "recount_us": round(us_full2, 1),
                "speedup_vs_recount": round(us_full2 / us_dec, 3),
                "delta_matches_recount": True},
        },
    }
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    row("triangle_bench_json", 0.0, str(_OUT.name))
