"""Paper Figs. 3/4/5 — insert / delete / query throughput, Meerkat vs the
Hornet-like baseline, for bulk loads and small batches (2K/4K/8K)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (empty, ensure_capacity, delete_edges, from_edges_host,
                        insert_edges, plan_buckets, query_edges)
from repro.data.synth import rmat_edges

from . import hornet_like as HL
from .timing import row, time_fn


def pad(a, n):
    out = np.full(n, 0xFFFFFFFF, np.uint32)
    out[:len(a)] = a
    return jnp.asarray(out)


def run(scale: str = "quick"):
    V, E = (20000, 150000) if scale == "quick" else (200000, 2000000)
    src, dst = rmat_edges(V, E, seed=0)
    E = len(src)

    # --- bulk build (Fig. 3 'entire graph') -------------------------------
    def build_meerkat():
        bc = plan_buckets(V, np.bincount(src, minlength=V))
        g = empty(V, bc, E // 64 + V + 1024)
        B = 8192
        for i in range(0, E, B):
            g = ensure_capacity(g, B)
            g, _ = insert_edges(g, pad(src[i:i + B], B), pad(dst[i:i + B], B))
        return g

    us = time_fn(build_meerkat, iters=2, warmup=1)
    row("insert_bulk_meerkat", us, f"edges={E};Meps={E / us:.2f}")

    def build_hornet():
        g = HL.from_edges_host(V, src[:1], dst[:1], slack=4.0)
        B = 8192
        for i in range(0, E, B):
            g, _ = HL.insert_edges(g, pad(src[i:i + B], B),
                                   pad(dst[i:i + B], B))
        return g
    us_h = time_fn(build_hornet, iters=2, warmup=1)
    row("insert_bulk_hornet_like", us_h, f"speedup={us_h / us:.2f}x")

    # --- small-batch insert / delete (Figs. 3, 4) -------------------------
    g0 = from_edges_host(V, src, dst, hashing=True, slack_slabs=4096)
    h0 = HL.from_edges_host(V, src, dst, slack=4.0)
    rng = np.random.default_rng(1)
    for bs in (2048, 4096, 8192):
        new_s = rng.integers(0, V, bs).astype(np.uint32)
        new_d = rng.integers(0, V, bs).astype(np.uint32)
        gm = ensure_capacity(g0, bs + 1)
        us_m = time_fn(lambda: insert_edges(gm, pad(new_s, bs),
                                            pad(new_d, bs)))
        us_h = time_fn(lambda: HL.insert_edges(h0, pad(new_s, bs),
                                               pad(new_d, bs)))
        row(f"insert_batch{bs}_meerkat", us_m,
            f"Meps={bs / us_m:.2f}")
        row(f"insert_batch{bs}_hornet_like", us_h,
            f"speedup={us_h / us_m:.2f}x")

        del_idx = rng.choice(E, bs, replace=False)
        ds, dd = src[del_idx], dst[del_idx]
        us_m = time_fn(lambda: delete_edges(g0, pad(ds, bs), pad(dd, bs)))
        us_h = time_fn(lambda: HL.delete_edges(h0, pad(ds, bs), pad(dd, bs)))
        row(f"delete_batch{bs}_meerkat", us_m, f"Meps={bs / us_m:.2f}")
        row(f"delete_batch{bs}_hornet_like", us_h,
            f"speedup={us_h / us_m:.2f}x")

    # --- query (Fig. 5): random batches 2^14..2^16 (scaled from 2^16..2^20)
    for logq in (14, 15, 16):
        Q = 1 << logq
        qs = rng.integers(0, V, Q).astype(np.uint32)
        qd = rng.integers(0, V, Q).astype(np.uint32)
        us_m = time_fn(lambda: query_edges(g0, jnp.asarray(qs),
                                           jnp.asarray(qd)))
        us_h = time_fn(lambda: HL.query_edges(h0, jnp.asarray(qs),
                                              jnp.asarray(qd)))
        row(f"query_2e{logq}_meerkat", us_m, f"Mqps={Q / us_m:.2f}")
        row(f"query_2e{logq}_hornet_like", us_h,
            f"speedup={us_h / us_m:.2f}x")
